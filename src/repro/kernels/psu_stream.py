"""Pallas TPU kernel: the fused PSU transmit pipeline.

One grid step runs the paper's *entire* hardware dataflow for a block of
packets in a single VMEM pass (DESIGN.md §3.2):

    popcount -> bucket encode -> histogram/prefix-sum -> rank
    -> reorder (inputs + paired weights) -> flit pack -> BT accumulate

This replaces the seed's three-step path (``psu_sort`` launch -> host
``take_along_axis`` gather -> ``bt_count`` launch) with one kernel launch per
block: the reordered stream never leaves VMEM between the sort and the BT
measurement.

Reorder stage: the seed kernel materialised ``order`` with an O(N^2) VPU
broadcast-compare against an iota plane and then gathered on the host.  Here
``order`` is derived from ``rank`` directly: the one-hot of ``rank`` is a
permutation *matrix*, so a single batched MXU contraction of the stacked
payload ``[iota, inputs, weights]`` against it simultaneously yields
``order`` (= permuted iota), the reordered inputs and the reordered weights
— the hardware's scatter-SRAM write expressed as one matrix product instead
of per-output compare/select reductions.  The one-hot *compare* formulation
survives only as the test oracle (``repro.core.sorting.invert_permutation``,
``repro.kernels.ref``).

Float32 is used for the contraction (MXU-native); all operands are < 2^24 so
the arithmetic is exact.

VMEM: for BP=64 packets of N=64 bytes the permutation-matrix block is
(64, 64, 64) f32 = 1 MiB and the bucket one-hot (64, 64, K<=9) is ~150 KiB —
comfortably inside a v5e core's VMEM.  Cross-block flit boundaries and
padded packets are patched up by the ``ops.py`` wrapper with O(grid) jnp
arithmetic (no extra kernel launch).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .psu import _popcount_bits, _rank_block

__all__ = ["psu_stream_pallas"]


def _psu_stream_kernel(
    x_ref,
    w_ref,
    order_ref,
    rank_ref,
    stream_ref,
    bt_ref,
    *,
    width: int,
    k: int | None,
    descending: bool,
    input_lanes: int,
    weight_lanes: int,
    pack: str,
):
    """Sort, reorder, pack and measure one (BP, N) block of packets."""
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    bp, n = x.shape
    flits = n // input_lanes

    # --- popcount / bucket / histogram / prefix-sum / index mapping ---
    # (shared with the standalone sort kernel: psu._rank_block)
    rank = _rank_block(x, width=width, k=k, descending=descending)

    # --- reorder stage: one permutation-matrix product for everything ---
    # perm[b, i, j] = [rank_i == j]; contracting [iota; x; w] with it gives
    # order, ordered inputs and ordered weights in a single MXU pass.
    iota_j = lax.broadcasted_iota(jnp.int32, (bp, n, n), 2)
    perm = (rank[:, :, None] == iota_j).astype(jnp.float32)  # (BP, N, N)
    iota_i = lax.broadcasted_iota(jnp.int32, (bp, n), 1)
    payload = jnp.stack([iota_i, x, w], axis=1).astype(jnp.float32)  # (BP,3,N)
    moved = lax.dot_general(
        payload,
        perm,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).astype(jnp.int32)  # (BP, 3, N)
    order = moved[:, 0, :]
    xs = moved[:, 1, :]
    ws = moved[:, 2, :]
    order_ref[...] = order
    rank_ref[...] = rank

    # --- flit-pack stage ---
    if pack == "lane":
        fi = xs.reshape(bp, input_lanes, flits).transpose(0, 2, 1)
    else:  # "row"
        fi = xs.reshape(bp, flits, input_lanes)
    if weight_lanes:
        if pack == "lane":
            fw = ws.reshape(bp, weight_lanes, flits).transpose(0, 2, 1)
        else:
            fw = ws.reshape(bp, flits, weight_lanes)
        flit_block = jnp.concatenate([fi, fw], axis=-1)
    else:
        flit_block = fi
    lanes = input_lanes + weight_lanes
    flit_block = flit_block.reshape(bp * flits, lanes)
    stream_ref[...] = flit_block

    # --- BT-accumulate stage (block-internal boundaries, split by side) ---
    flips = _popcount_bits(
        jnp.bitwise_xor(flit_block[:-1], flit_block[1:]), 8
    )  # byte lanes are 8-bit regardless of the element sort width
    bt_ref[0, 0] = flips[:, :input_lanes].sum()
    bt_ref[0, 1] = (
        flips[:, input_lanes:].sum() if weight_lanes else jnp.int32(0)
    )


def psu_stream_pallas(
    inputs: jax.Array,
    weights: jax.Array,
    *,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    input_lanes: int = 8,
    weight_lanes: int = 8,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused PSU transmit pipeline over a batch of packets.

    Args:
      inputs: (P, N) integer packets; P must be a multiple of
        ``block_packets`` (the ``ops.py`` wrapper pads).
      weights: (P, N) paired weight bytes (ignored when ``weight_lanes=0``
        — pass zeros).
      width: element bit width W for the sort keys.
      k: APP bucket count, or ``None`` for the exact ACC unit.
      descending: sort high-popcount-first.
      input_lanes / weight_lanes: bytes of each side per flit;
        ``weight_lanes=0`` transmits an input-only stream.
      pack: ``"lane"`` (PSU lane-major packing, paper Fig. 2) or ``"row"``.
      block_packets: packets per grid step.
      interpret: run the kernel body in Python (CPU validation mode).

    Returns:
      (order, rank, stream, bt): int32 (P, N), int32 (P, N), int32
      (P*F, input_lanes+weight_lanes) packed flit rows, and int32 (G, 2)
      per-block BT partials split (input side, weight side) over the
      block-internal flit boundaries.
    """
    p, n = inputs.shape
    if p % block_packets != 0:
        raise ValueError(f"P={p} not a multiple of block_packets={block_packets}")
    if n % input_lanes != 0:
        raise ValueError(f"packet size {n} not divisible by input_lanes={input_lanes}")
    if weight_lanes and n % weight_lanes != 0:
        raise ValueError(
            f"packet size {n} not divisible by weight_lanes={weight_lanes}"
        )
    if pack not in ("lane", "row"):
        raise ValueError(f"fused kernel supports pack 'lane'|'row', got {pack!r}")
    flits = n // input_lanes
    lanes = input_lanes + weight_lanes
    grid = (p // block_packets,)
    kern = functools.partial(
        _psu_stream_kernel,
        width=width,
        k=k,
        descending=descending,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
    )
    pk_spec = pl.BlockSpec((block_packets, n), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((p, n), jnp.int32),
        jax.ShapeDtypeStruct((p, n), jnp.int32),
        jax.ShapeDtypeStruct((p * flits, lanes), jnp.int32),
        jax.ShapeDtypeStruct((p // block_packets, 2), jnp.int32),
    ]
    out_specs = [
        pk_spec,
        pk_spec,
        pl.BlockSpec((block_packets * flits, lanes), lambda i: (i, 0)),
        pl.BlockSpec((1, 2), lambda i: (i, 0)),
    ]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pk_spec, pk_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(inputs.astype(jnp.int32), weights.astype(jnp.int32))
