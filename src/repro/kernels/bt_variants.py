"""Pallas TPU kernel: batched multi-variant ordered-BT measurement.

The design-space engine (``repro.dse``) compares MANY sorting-unit
configurations — precise (ACC) vs every bucket count k, ascending vs
descending, against the unsorted and column-major baselines — on the same
packet stream.  Measuring each configuration with ``psu_stream``/``bt_count``
costs one kernel launch per configuration; this kernel puts the *variant*
axis inside a single launch instead.

One grid step loads a (BP, N) packet block into VMEM, runs the popcount
stage ONCE, and then — for every variant in the static tuple — runs the
variant's bucket encoder, the shared counting-sort rank machinery
(``psu._rank_from_keys``), the permutation-matrix reorder of
``psu_stream.py``, the flit pack and the BT accumulate.  The variant loop is
a Python loop over a static tuple, so it unrolls at trace time: all variants
live in the ONE traced kernel and the popcount tensor is shared by every
bucketing derived from it.

A variant is a ``Variant(key, k, descending)`` triple:

  * ``key='acc'``            — exact popcount keys (W+1 buckets),
  * ``key='app'``            — the k-bucket approximate encoder,
  * ``key='none'``           — the unsorted baseline (identity order),
  * ``key='column_major'``   — the fixed column-major re-traversal of the
    (flits, lanes) packet matrix (a layout, not a data-dependent sort — it
    lowers to a reshape/transpose, no rank computation).

Per block the kernel emits (a) per-variant (input-side, weight-side) BT
partials over the block-internal flit boundaries, and (b) each variant's
first and last packed flit row, from which the ``ops.py`` wrapper patches
the G-1 inter-block boundaries with O(grid) jnp arithmetic — the same
partial/patch split as ``psu_stream.py``, extended per variant.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .psu import _popcount_bits, _rank_from_keys

__all__ = ["Variant", "VARIANT_KEYS", "bt_variants_pallas"]

VARIANT_KEYS = ("none", "column_major", "acc", "app")


class Variant(NamedTuple):
    """One measured ordering configuration of the variant-BT kernel.

    ``key`` is a packet-granularity ordering ('none' | 'column_major' |
    'acc' | 'app'); ``k`` is the APP bucket count (None for every other
    key); ``descending`` flips the sort direction (ACC/APP only).
    """

    key: str = "acc"
    k: int | None = None
    descending: bool = False


def validate_variants(
    variants: tuple[Variant, ...], width: int
) -> tuple[Variant, ...]:
    """Check a static variant tuple against the kernel's contract."""
    if not variants:
        raise ValueError("need at least one variant")
    out = []
    for v in variants:
        v = Variant(*v)
        if v.key not in VARIANT_KEYS:
            raise ValueError(
                f"unknown variant key {v.key!r}; choose from {VARIANT_KEYS}"
            )
        if v.key == "app":
            if v.k is None or not 1 <= v.k <= width + 1:
                raise ValueError(
                    f"variant {v}: 'app' needs k in [1, {width + 1}]"
                )
        elif v.k is not None:
            raise ValueError(f"variant {v}: k is only meaningful for 'app'")
        if v.descending and v.key not in ("acc", "app"):
            raise ValueError(
                f"variant {v}: descending applies to sorted keys only"
            )
        out.append(v)
    return tuple(out)


def _bt_variants_kernel(
    x_ref,
    w_ref,
    bt_ref,
    edge_ref,
    *,
    variants: tuple[Variant, ...],
    width: int,
    input_lanes: int,
    weight_lanes: int,
    pack: str,
):
    """Measure ordered BT of one (BP, N) block under every variant."""
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    bp, n = x.shape
    flits = n // input_lanes

    # --- popcount stage: ONCE per block, shared by every bucketing ---
    pc = _popcount_bits(x, width)

    def _flit(values, lanes):
        if pack == "lane":
            return values.reshape(bp, lanes, flits).transpose(0, 2, 1)
        return values.reshape(bp, flits, lanes)

    for v, (key_name, k, descending) in enumerate(variants):
        if key_name in ("acc", "app"):
            # --- bucket encoder + shared rank machinery (psu.py) ---
            if key_name == "acc":
                key, nb = pc, width + 1
            else:
                key, nb = (pc * k) // (width + 1), k
            if descending:
                key = (nb - 1) - key
            rank = _rank_from_keys(key, nb)
            # --- reorder: permutation-matrix MXU product (psu_stream.py);
            # no iota row — the DSE path needs streams, not `order` ---
            iota_j = lax.broadcasted_iota(jnp.int32, (bp, n, n), 2)
            perm = (rank[:, :, None] == iota_j).astype(jnp.float32)
            payload = jnp.stack([x, w], axis=1).astype(jnp.float32)
            moved = lax.dot_general(
                payload,
                perm,
                dimension_numbers=(((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32,
            ).astype(jnp.int32)  # (BP, 2, N)
            xs, ws = moved[:, 0, :], moved[:, 1, :]
        elif key_name == "column_major":
            # fixed layout permutation — output position (l*F + f) carries
            # input element (f*L + l): a transpose of the (F, L) packet view
            xs = x.reshape(bp, flits, input_lanes).transpose(0, 2, 1)
            xs = xs.reshape(bp, n)
            ws = w.reshape(bp, flits, input_lanes).transpose(0, 2, 1)
            ws = ws.reshape(bp, n)
        else:  # 'none'
            xs, ws = x, w

        # --- flit-pack + BT-accumulate stages (as in psu_stream.py) ---
        if weight_lanes:
            flit_block = jnp.concatenate(
                [_flit(xs, input_lanes), _flit(ws, weight_lanes)], axis=-1
            )
        else:
            flit_block = _flit(xs, input_lanes)
        lanes = input_lanes + weight_lanes
        stream = flit_block.reshape(bp * flits, lanes)
        flips = _popcount_bits(
            jnp.bitwise_xor(stream[:-1], stream[1:]), 8
        )  # byte lanes are 8-bit regardless of the element sort width
        bt_ref[0, v, 0] = flips[:, :input_lanes].sum()
        bt_ref[0, v, 1] = (
            flips[:, input_lanes:].sum() if weight_lanes else jnp.int32(0)
        )
        edge_ref[0, v, 0, :] = stream[0]
        edge_ref[0, v, 1, :] = stream[-1]


def bt_variants_pallas(
    inputs: jax.Array,
    weights: jax.Array,
    *,
    variants: tuple[Variant, ...],
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int = 0,
    pack: str = "lane",
    block_packets: int = 64,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-variant ordered BT of a (P, N) packet batch in ONE launch.

    Args:
      inputs: (P, N) int packets; P must be a multiple of ``block_packets``
        (the ``ops.py`` wrapper pads with zero packets — zeros sort to zeros
        under every variant, and the wrapper subtracts the one spurious
        boundary into the padded tail).
      weights: (P, N) paired weight bytes (zeros when ``weight_lanes=0``).
      variants: static tuple of :class:`Variant` configurations.
      width: element bit width W of the sort keys.
      input_lanes / weight_lanes: bytes of each side per flit.
      pack: 'lane' (PSU lane-major, paper Fig. 2) or 'row'.
      block_packets: packets per grid step.
      interpret: run the kernel body in Python (CPU validation mode).

    Returns:
      (partials, edges): int32 (G, V, 2) per-block (input, weight) BT
      partials over block-internal boundaries, and int32 (G, V, 2, lanes)
      per-block first/last packed flit rows per variant (for the wrapper's
      inter-block boundary patch).
    """
    variants = validate_variants(variants, width)
    p, n = inputs.shape
    if p % block_packets != 0:
        raise ValueError(f"P={p} not a multiple of block_packets={block_packets}")
    if n % input_lanes != 0:
        raise ValueError(f"packet size {n} not divisible by input_lanes={input_lanes}")
    if weight_lanes not in (0, input_lanes):
        raise ValueError(
            "variant kernel needs a symmetric (or absent) weight side: "
            f"weight_lanes={weight_lanes} vs input_lanes={input_lanes}"
        )
    if pack not in ("lane", "row"):
        raise ValueError(f"variant kernel supports pack 'lane'|'row', got {pack!r}")
    nv = len(variants)
    lanes = input_lanes + weight_lanes
    grid = (p // block_packets,)
    kern = functools.partial(
        _bt_variants_kernel,
        variants=variants,
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
    )
    pk_spec = pl.BlockSpec((block_packets, n), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((p // block_packets, nv, 2), jnp.int32),
        jax.ShapeDtypeStruct((p // block_packets, nv, 2, lanes), jnp.int32),
    ]
    out_specs = [
        pl.BlockSpec((1, nv, 2), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, nv, 2, lanes), lambda i: (i, 0, 0, 0)),
    ]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pk_spec, pk_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(inputs.astype(jnp.int32), weights.astype(jnp.int32))
