"""Pallas TPU kernel: batched multi-codec x multi-ordering BT measurement.

``repro.codec`` makes "ordering vs coding vs ordering∘coding" a measured
axis: every configuration pairs a packet ordering (the paper's PSU) with a
link codec (bus-invert / gray / sign-magnitude / transition signaling).
Measuring each pair with ``psu_stream`` + a jnp codec + ``bt_count`` costs
one (or more) kernel launches per configuration; this kernel puts the
whole *codec x ordering* grid inside ONE launch.

One grid step loads a (BP, N) packet block, runs the popcount stage ONCE,
and then — for every static config — derives the ordering (the shared
``psu._rank_from_keys`` counting-sort machinery and the permutation-matrix
reorder of ``bt_variants.py``), packs the flit stream, applies the codec
and accumulates per-side BT plus invert-line transitions.  Configs sharing
an ordering share its reorder; codecs are applied per config on the shared
stream.

Codec state across blocks (DESIGN.md §11):

  * stateless codecs (``none`` / ``gray`` / ``sign_magnitude``) are per-byte
    maps — per-block edge flits patch the G-1 inter-block boundaries
    exactly as in ``bt_variants.py``;
  * ``transition`` signaling's wire depends on the whole history, but its
    boundary flips equal the *data* flit's popcount, so blocks emit data
    edges and the wrapper adds each block's first-flit popcount;
  * ``bus_invert``'s sequential invert decision is re-expressed as a
    per-block prefix scan: the recurrence v_t = tie_t ? 0 : h_t ^ v_{t-1}
    (h/tie from vectorized pairwise data HDs) collapses to a prefix-XOR
    with tie resets, evaluated for BOTH possible entry states — the two
    branches of a block are complement-or-equal throughout, so the block's
    coding is fully determined by its first invert bit.  The kernel emits
    per-branch, per-partition BT partials and edge wire/invert states; the
    ``ops.py`` wrapper folds the O(G) inter-block carry (choosing each
    block's branch from the previous block's last wire flit) in plain jnp.

Zero-padded tail packets are masked *inside* the kernel (each block knows
its valid flit count from ``program_id``), so non-block-multiple P needs no
wrapper-side subtraction.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.coding import (
    bus_invert_partitions as _partitions,
    gray_encode_bytes,
    sign_magnitude_encode_bytes,
)

from .bt_variants import Variant, validate_variants
from .psu import _popcount_bits, _rank_from_keys

__all__ = [
    "CodecVariant",
    "CODEC_SCHEMES",
    "validate_codec_variants",
    "bt_codecs_pallas",
]

CODEC_SCHEMES = ("none", "gray", "sign_magnitude", "transition", "bus_invert")


class CodecVariant(NamedTuple):
    """One measured (ordering, codec) configuration of the codec-BT kernel.

    ``key`` / ``k`` / ``descending`` are the ordering axes of
    ``bt_variants.Variant``; ``codec`` is a static scheme id from
    ``CODEC_SCHEMES``; ``partition`` is the bus-invert group width in lanes
    (None = one invert line over the whole flit; meaningless otherwise).
    """

    key: str = "acc"
    k: int | None = None
    descending: bool = False
    codec: str = "none"
    partition: int | None = None

    @property
    def ordering(self) -> Variant:
        return Variant(self.key, self.k, self.descending)


def validate_codec_variants(
    configs: tuple[CodecVariant, ...], width: int, lanes: int
) -> tuple[CodecVariant, ...]:
    """Check a static config tuple against the kernel's contract."""
    if not configs:
        raise ValueError("need at least one codec config")
    out = []
    for cfg in configs:
        cfg = CodecVariant(*cfg)
        validate_variants((cfg.ordering,), width)
        if cfg.codec not in CODEC_SCHEMES:
            raise ValueError(
                f"config {cfg}: unknown codec scheme {cfg.codec!r}; "
                f"choose from {CODEC_SCHEMES}"
            )
        if cfg.codec == "bus_invert":
            _partitions(lanes, cfg.partition)
        elif cfg.partition is not None:
            raise ValueError(
                f"config {cfg}: partition is only meaningful for 'bus_invert'"
            )
        out.append(cfg)
    return tuple(out)


def max_partitions(
    configs: tuple[CodecVariant, ...], lanes: int
) -> int:
    """Invert-line slots the kernel's outputs must provide (>= 1)."""
    return max(
        [1]
        + [
            _partitions(lanes, c.partition)[0]
            for c in configs
            if c.codec == "bus_invert"
        ]
    )


def _bus_invert_bits(hd: jax.Array, lbits: int) -> tuple[jax.Array, jax.Array]:
    """Invert-line states for both entry branches from pairwise data HDs.

    ``hd`` is (T-1, P) Hamming distances between consecutive data flit
    groups.  The sequential decision v_t = [2*HD(d_t, w_{t-1}) > L] obeys
    v_t = tie_t ? 0 : h_t ^ v_{t-1} (h_t = [2*HD_t > L], tie_t =
    [2*HD_t == L]), which is a prefix-XOR with resets at ties — evaluated
    here with one cumsum and one cummax instead of a sequential scan.
    Returns (v0, v1), both (T, P), for entry states v_0 = 0 and v_0 = 1.
    """
    tm1, npart = hd.shape
    h = (2 * hd > lbits).astype(jnp.int32)
    tie = (2 * hd == lbits).astype(jnp.int32)
    xpre = jnp.cumsum(h, axis=0) & 1  # X_t = h_1 ^ ... ^ h_t
    tpos = lax.broadcasted_iota(jnp.int32, (tm1, npart), 0) + 1
    packed = jnp.where(tie == 1, 2 * tpos + xpre, 0)  # (t, X_t) at ties
    cmax = lax.cummax(packed, axis=0)  # carries the most recent tie
    xr = jnp.where(cmax > 0, cmax & 1, 0)  # X at the last tie (else 0)
    zeros = jnp.zeros((1, npart), jnp.int32)
    v0 = jnp.concatenate([zeros, xpre ^ xr], axis=0)
    # no tie yet -> the entry bit still propagates: v1 = v0 ^ [no tie <= t]
    notie = jnp.concatenate(
        [zeros + 1, (cmax == 0).astype(jnp.int32)], axis=0
    )
    return v0, v0 ^ notie


def _bt_codecs_kernel(
    x_ref,
    w_ref,
    bt_ref,
    edge_ref,
    inv_edge_ref,
    *,
    configs: tuple[CodecVariant, ...],
    width: int,
    input_lanes: int,
    weight_lanes: int,
    pack: str,
    real_rows: int,
    pmax: int,
):
    """Measure coded + ordered BT of one (BP, N) block under every config."""
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    bp, n = x.shape
    flits = n // input_lanes
    lanes = input_lanes + weight_lanes
    rows = bp * flits
    g = pl.program_id(0)
    valid = jnp.minimum(jnp.int32(rows), jnp.int32(real_rows) - g * rows)

    row_idx = lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
    bmask = (row_idx[1:] < valid).astype(jnp.int32)  # (rows-1, 1) boundaries

    def _last_valid(arr):  # (rows, L) -> (L,): the row at index valid-1
        onehot = (row_idx == valid - 1).astype(jnp.int32)
        return (arr * onehot).sum(axis=0)

    def _flit(values, ln):
        if pack == "lane":
            return values.reshape(bp, ln, flits).transpose(0, 2, 1)
        return values.reshape(bp, flits, ln)

    # --- popcount stage: ONCE per block, shared by every bucketing ---
    pc = _popcount_bits(x, width)

    # --- one reordered + packed stream per unique ordering ---
    streams: dict[Variant, jax.Array] = {}
    for cfg in configs:
        if cfg.ordering in streams:
            continue
        key_name, k, descending = cfg.ordering
        if key_name in ("acc", "app"):
            if key_name == "acc":
                key, nb = pc, width + 1
            else:
                key, nb = (pc * k) // (width + 1), k
            if descending:
                key = (nb - 1) - key
            rank = _rank_from_keys(key, nb)
            iota_j = lax.broadcasted_iota(jnp.int32, (bp, n, n), 2)
            perm = (rank[:, :, None] == iota_j).astype(jnp.float32)
            payload = jnp.stack([x, w], axis=1).astype(jnp.float32)
            moved = lax.dot_general(
                payload,
                perm,
                dimension_numbers=(((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32,
            ).astype(jnp.int32)
            xs, ws = moved[:, 0, :], moved[:, 1, :]
        elif key_name == "column_major":
            xs = x.reshape(bp, flits, input_lanes).transpose(0, 2, 1)
            xs = xs.reshape(bp, n)
            ws = w.reshape(bp, flits, input_lanes).transpose(0, 2, 1)
            ws = ws.reshape(bp, n)
        else:  # 'none'
            xs, ws = x, w
        if weight_lanes:
            flit_block = jnp.concatenate(
                [_flit(xs, input_lanes), _flit(ws, weight_lanes)], axis=-1
            )
        else:
            flit_block = _flit(xs, input_lanes)
        streams[cfg.ordering] = flit_block.reshape(rows, lanes)

    # --- codec + BT-accumulate per config on the shared streams ---
    for ci, cfg in enumerate(configs):
        stream = streams[cfg.ordering]
        zero_inv = jnp.zeros((2, 2, pmax), jnp.int32)

        if cfg.codec in ("none", "gray", "sign_magnitude"):
            if cfg.codec == "gray":
                wire = gray_encode_bytes(stream)
            elif cfg.codec == "sign_magnitude":
                wire = sign_magnitude_encode_bytes(stream)
            else:
                wire = stream
            flips = _popcount_bits(wire[1:] ^ wire[:-1], 8) * bmask
            row = jnp.stack(
                [
                    flips[:, :input_lanes].sum(),
                    flips[:, input_lanes:].sum() if weight_lanes else jnp.int32(0),
                    jnp.int32(0),
                ]
            )
            part = jnp.broadcast_to(row, (2, 1, 3))
            edge = jnp.stack([wire[0], _last_valid(wire)])  # (2, lanes)
            bt_ref[0, ci] = jnp.pad(part, ((0, 0), (0, pmax - 1), (0, 0)))
            edge_ref[0, ci] = jnp.broadcast_to(edge, (2, 2, lanes))
            inv_edge_ref[0, ci] = zero_inv

        elif cfg.codec == "transition":
            # wire_t ^ wire_{t-1} == data_t: boundary flips = data popcount
            ppc = _popcount_bits(stream, 8)
            contrib = ppc[1:] * bmask
            row = jnp.stack(
                [
                    contrib[:, :input_lanes].sum(),
                    contrib[:, input_lanes:].sum()
                    if weight_lanes
                    else jnp.int32(0),
                    jnp.int32(0),
                ]
            )
            part = jnp.broadcast_to(row, (2, 1, 3))
            # edges carry DATA flits (the wrapper adds first-flit popcounts)
            edge = jnp.stack([stream[0], _last_valid(stream)])
            bt_ref[0, ci] = jnp.pad(part, ((0, 0), (0, pmax - 1), (0, 0)))
            edge_ref[0, ci] = jnp.broadcast_to(edge, (2, 2, lanes))
            inv_edge_ref[0, ci] = zero_inv

        else:  # bus_invert
            npart, pw = _partitions(lanes, cfg.partition)
            lbits = 8 * pw
            d = stream.reshape(rows, npart, pw)
            dpc = _popcount_bits(d[1:] ^ d[:-1], 8)  # (rows-1, npart, pw)
            v0, v1 = _bus_invert_bits(dpc.sum(axis=-1), lbits)
            # input/weight lane split inside each partition: global lane id
            # part*pw + j < input_lanes (iota, not a captured constant)
            lane_id = lax.broadcasted_iota(
                jnp.int32, (npart, pw), 0
            ) * pw + lax.broadcasted_iota(jnp.int32, (npart, pw), 1)
            in_mask = (lane_id < input_lanes).astype(jnp.int32)
            parts, edges, inv_edges = [], [], []
            for v in (v0, v1):
                e = v[1:] ^ v[:-1]  # (rows-1, npart) invert-line flips
                lane_flips = jnp.where(e[:, :, None] == 1, 8 - dpc, dpc)
                lane_flips = lane_flips * bmask[:, :, None]
                bt_in = (lane_flips * in_mask).sum(axis=(0, 2))
                bt_wg = (lane_flips * (1 - in_mask)).sum(axis=(0, 2))
                aux = (e * bmask).sum(axis=0)
                parts.append(jnp.stack([bt_in, bt_wg, aux], axis=-1))
                wire = (d ^ (v[:, :, None] * 0xFF)).reshape(rows, lanes)
                edges.append(jnp.stack([wire[0], _last_valid(wire)]))
                inv_edges.append(jnp.stack([v[0], _last_valid(v)]))
            bt_ref[0, ci] = jnp.pad(
                jnp.stack(parts), ((0, 0), (0, pmax - npart), (0, 0))
            )
            edge_ref[0, ci] = jnp.stack(edges)
            inv_edge_ref[0, ci] = jnp.pad(
                jnp.stack(inv_edges), ((0, 0), (0, 0), (0, pmax - npart))
            )


def bt_codecs_pallas(
    inputs: jax.Array,
    weights: jax.Array,
    *,
    configs: tuple[CodecVariant, ...],
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int = 0,
    pack: str = "lane",
    block_packets: int = 64,
    real_packets: int | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-config coded BT partials of a (P, N) packet batch in ONE launch.

    Args:
      inputs / weights: (P, N) int packets; P a multiple of
        ``block_packets`` (the ``ops.py`` wrapper zero-pads; padded flits
        are masked inside the kernel via ``real_packets``).
      configs: static tuple of :class:`CodecVariant` configurations.
      real_packets: packets that are real data (default: all of P).

    Returns:
      (partials, edges, inv_edges):
        * int32 (G, C, 2, PMAX, 3) per-block, per-entry-branch,
          per-partition (input, weight, invert-line) BT partials over
          block-internal valid boundaries (branches are identical for every
          codec except bus-invert; non-partitioned codecs use slot 0);
        * int32 (G, C, 2, 2, lanes) per-branch first/last wire rows (DATA
          rows for 'transition');
        * int32 (G, C, 2, 2, PMAX) per-branch first/last invert-line
          states (bus-invert only, zeros otherwise).
    """
    p, n = inputs.shape
    lanes = input_lanes + weight_lanes
    configs = validate_codec_variants(configs, width, lanes)
    if p % block_packets != 0:
        raise ValueError(f"P={p} not a multiple of block_packets={block_packets}")
    if n % input_lanes != 0:
        raise ValueError(f"packet size {n} not divisible by input_lanes={input_lanes}")
    if weight_lanes not in (0, input_lanes):
        raise ValueError(
            "codec kernel needs a symmetric (or absent) weight side: "
            f"weight_lanes={weight_lanes} vs input_lanes={input_lanes}"
        )
    if pack not in ("lane", "row"):
        raise ValueError(f"codec kernel supports pack 'lane'|'row', got {pack!r}")
    if real_packets is None:
        real_packets = p
    if not 0 < real_packets <= p:
        raise ValueError(f"real_packets={real_packets} outside (0, {p}]")
    nc = len(configs)
    flits = n // input_lanes
    pmax = max_partitions(configs, lanes)
    grid = (p // block_packets,)
    kern = functools.partial(
        _bt_codecs_kernel,
        configs=configs,
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        pack=pack,
        real_rows=real_packets * flits,
        pmax=pmax,
    )
    pk_spec = pl.BlockSpec((block_packets, n), lambda i: (i, 0))
    gblocks = p // block_packets
    out_shape = [
        jax.ShapeDtypeStruct((gblocks, nc, 2, pmax, 3), jnp.int32),
        jax.ShapeDtypeStruct((gblocks, nc, 2, 2, lanes), jnp.int32),
        jax.ShapeDtypeStruct((gblocks, nc, 2, 2, pmax), jnp.int32),
    ]
    out_specs = [
        pl.BlockSpec((1, nc, 2, pmax, 3), lambda i: (i, 0, 0, 0, 0)),
        pl.BlockSpec((1, nc, 2, 2, lanes), lambda i: (i, 0, 0, 0, 0)),
        pl.BlockSpec((1, nc, 2, 2, pmax), lambda i: (i, 0, 0, 0, 0)),
    ]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pk_spec, pk_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(inputs.astype(jnp.int32), weights.astype(jnp.int32))
