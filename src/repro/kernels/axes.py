"""Pallas TPU kernel: the ONE multi-axis BT measurement core.

Four near-duplicate kernels used to live in this package — ``psu_stream``
(fused TX pipeline), ``bt_links`` (per-link NoC batch), ``bt_variants``
(design-grid variant batch) and ``bt_codecs`` (codec x ordering batch) —
each reimplementing popcount -> bucket -> rank -> permutation-reorder ->
flit-pack -> BT with its own padding convention.  This module replaces all
four with one kernel whose launch carries three orthogonal axes:

  * **link** — grid dimension 0: each grid row measures one independent
    stream (a NoC link, a workload stream, a point-to-point wire).  Links
    may be jagged: a ``valid`` vector carries each link's real packet
    count and everything past it is masked *inside* the kernel.
  * **variant** (ordering) — static, unrolled at trace time: 'none' /
    'column_major' / 'acc' / 'app'(k) x direction.  One popcount pass per
    block is shared by every bucketing; one permutation-matrix reorder is
    shared by every config naming the same ordering.
  * **codec** — static, unrolled at trace time: 'none' / 'gray' /
    'sign_magnitude' / 'transition' / 'bus_invert'(partition), applied to
    the assembled wire per config (DESIGN.md §11/§12).

One unified padding/masking convention (DESIGN.md §12): the wrapper pads
the packet axis to a block multiple with zero packets and the link axis
with zero links; the kernel masks every flit boundary at or past each
link's ``valid`` row count, so padded flits contribute ZERO data-side BT
**and zero aux (invert-line) BT** — in particular a bus-invert decision is
never evaluated on a padded flit (the old repeated-flit convention was
BT-neutral for data wires but could flip a coded link's invert line).

Cross-block state is the same partial/edge split as before, now per link:
each (link, block) emits per-config BT partials over its block-internal
valid boundaries plus first/last-valid edge flits (and bus-invert branch
states), from which the ``ops.py`` wrapper folds the O(G) inter-block
carry per link in plain jnp — no extra kernel launch.

The fused TX pipeline is this same kernel with ``emit_stream=True`` (one
link, one config): the permutation-matrix contraction then also yields
``order`` (permuted iota), ``rank`` and the packed wire stream, exactly as
the old ``psu_stream`` kernel did (DESIGN.md §3.2).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.coding import (
    bus_invert_partitions as _partitions,
    gray_encode_bytes,
    sign_magnitude_encode_bytes,
)

from .backend import default_backend
from .psu import _popcount_bits, _rank_from_keys

__all__ = [
    "Variant",
    "VARIANT_KEYS",
    "validate_variants",
    "CodecVariant",
    "CODEC_SCHEMES",
    "validate_codec_variants",
    "max_partitions",
    "bt_axes_pallas",
    "bt_axes_compiled",
]

VARIANT_KEYS = ("none", "column_major", "acc", "app")

CODEC_SCHEMES = ("none", "gray", "sign_magnitude", "transition", "bus_invert")


class Variant(NamedTuple):
    """One measured ordering configuration of the multi-axis kernel.

    ``key`` is a packet-granularity ordering ('none' | 'column_major' |
    'acc' | 'app'); ``k`` is the APP bucket count (None for every other
    key); ``descending`` flips the sort direction (ACC/APP only).
    """

    key: str = "acc"
    k: int | None = None
    descending: bool = False


class CodecVariant(NamedTuple):
    """One measured (ordering, codec) configuration of the multi-axis
    kernel.

    ``key`` / ``k`` / ``descending`` are the ordering axes of
    :class:`Variant`; ``codec`` is a static scheme id from
    ``CODEC_SCHEMES``; ``partition`` is the bus-invert group width in lanes
    (None = one invert line over the whole flit; meaningless otherwise).
    """

    key: str = "acc"
    k: int | None = None
    descending: bool = False
    codec: str = "none"
    partition: int | None = None

    @property
    def ordering(self) -> Variant:
        return Variant(self.key, self.k, self.descending)


def validate_variants(
    variants: tuple[Variant, ...], width: int
) -> tuple[Variant, ...]:
    """Check a static variant tuple against the kernel's contract."""
    if not variants:
        raise ValueError("need at least one variant")
    out = []
    for v in variants:
        v = Variant(*v)
        if v.key not in VARIANT_KEYS:
            raise ValueError(
                f"unknown variant key {v.key!r}; choose from {VARIANT_KEYS}"
            )
        if v.key == "app":
            if v.k is None or not 1 <= v.k <= width + 1:
                raise ValueError(
                    f"variant {v}: 'app' needs k in [1, {width + 1}]"
                )
        elif v.k is not None:
            raise ValueError(f"variant {v}: k is only meaningful for 'app'")
        if v.descending and v.key not in ("acc", "app"):
            raise ValueError(
                f"variant {v}: descending applies to sorted keys only"
            )
        out.append(v)
    return tuple(out)


def validate_codec_variants(
    configs: tuple[CodecVariant, ...], width: int, lanes: int
) -> tuple[CodecVariant, ...]:
    """Check a static config tuple against the kernel's contract."""
    if not configs:
        raise ValueError("need at least one codec config")
    out = []
    for cfg in configs:
        cfg = CodecVariant(*cfg)
        validate_variants((cfg.ordering,), width)
        if cfg.codec not in CODEC_SCHEMES:
            raise ValueError(
                f"config {cfg}: unknown codec scheme {cfg.codec!r}; "
                f"choose from {CODEC_SCHEMES}"
            )
        if cfg.codec == "bus_invert":
            _partitions(lanes, cfg.partition)
        elif cfg.partition is not None:
            raise ValueError(
                f"config {cfg}: partition is only meaningful for 'bus_invert'"
            )
        out.append(cfg)
    return tuple(out)


def max_partitions(
    configs: tuple[CodecVariant, ...], lanes: int
) -> int:
    """Invert-line slots the kernel's outputs must provide (>= 1)."""
    return max(
        [1]
        + [
            _partitions(lanes, c.partition)[0]
            for c in configs
            if c.codec == "bus_invert"
        ]
    )


def _bus_invert_bits(hd: jax.Array, lbits: int) -> tuple[jax.Array, jax.Array]:
    """Invert-line states for both entry branches from pairwise data HDs.

    ``hd`` is (T-1, P) Hamming distances between consecutive data flit
    groups.  The sequential decision v_t = [2*HD(d_t, w_{t-1}) > L] obeys
    v_t = tie_t ? 0 : h_t ^ v_{t-1} (h_t = [2*HD_t > L], tie_t =
    [2*HD_t == L]), which is a prefix-XOR with resets at ties — evaluated
    here with one cumsum and one cummax instead of a sequential scan.
    Returns (v0, v1), both (T, P), for entry states v_0 = 0 and v_0 = 1.
    """
    tm1, npart = hd.shape
    h = (2 * hd > lbits).astype(jnp.int32)
    tie = (2 * hd == lbits).astype(jnp.int32)
    xpre = jnp.cumsum(h, axis=0) & 1  # X_t = h_1 ^ ... ^ h_t
    tpos = lax.broadcasted_iota(jnp.int32, (tm1, npart), 0) + 1
    packed = jnp.where(tie == 1, 2 * tpos + xpre, 0)  # (t, X_t) at ties
    cmax = lax.cummax(packed, axis=0)  # carries the most recent tie
    xr = jnp.where(cmax > 0, cmax & 1, 0)  # X at the last tie (else 0)
    zeros = jnp.zeros((1, npart), jnp.int32)
    v0 = jnp.concatenate([zeros, xpre ^ xr], axis=0)
    # no tie yet -> the entry bit still propagates: v1 = v0 ^ [no tie <= t]
    notie = jnp.concatenate(
        [zeros + 1, (cmax == 0).astype(jnp.int32)], axis=0
    )
    return v0, v0 ^ notie


def _axes_block(
    x,
    w,
    remaining_rows,
    start_row=None,
    *,
    configs: tuple[CodecVariant, ...],
    width: int,
    input_lanes: int,
    weight_lanes: int,
    split_lanes: int,
    pack: str,
    pmax: int,
    emit_stream: bool,
    window_rows: int = 0,
    num_windows: int = 0,
):
    """Measure one (link, packet-block) cell under every static config.

    The backend-shared block math (DESIGN.md §13): the Pallas kernel calls
    this from its grid body, the compiled jnp backend ``vmap``s it over the
    link axis and ``lax.map``s it over packet blocks — the two paths run
    the SAME traced operations, so they are bit-exact by construction.

    Args:
      x / w: (BP, N) int32 packet payloads of this block.
      remaining_rows: int32 scalar — this link's valid flit rows minus the
        rows consumed by earlier blocks (may be <= 0: fully-padded block).
      start_row: int32 scalar — global flit-row index of this block's first
        row (activity mode only; windows are indexed globally so chunked
        and unchunked runs land toggles in the same window).
      window_rows / num_windows: static activity-window length (flit rows)
        and total window count; ``num_windows > 0`` enables the per-wire
        activity outputs (DESIGN.md §15).

    Returns:
      (bt (C, 2, PMAX, 3), edge (C, 2, 2, lanes), inv (C, 2, 2, PMAX))
      int32 partials; with activity also (act (C, 2, NW, WIRES),
      ones (C, 2, WIRES)) where WIRES = lanes*8 data wires (wire = lane*8
      + bit, LSB first) followed by PMAX invert-line wires; plus
      (order, rank, stream) with ``emit_stream``.
    """
    x = x.astype(jnp.int32)  # (BP, N)
    w = w.astype(jnp.int32)
    bp, n = x.shape
    flits = n // input_lanes
    lanes = input_lanes + weight_lanes
    rows = bp * flits
    act_on = num_windows > 0

    # --- the ONE masking convention: rows at or past this link's valid
    # count contribute nothing (data BT, aux BT, edge flits alike) ---
    valid = jnp.minimum(jnp.int32(rows), remaining_rows)
    row_idx = lax.broadcasted_iota(jnp.int32, (rows, 1), 0)
    bmask = (row_idx[1:] < valid).astype(jnp.int32)  # (rows-1, 1) boundaries

    if act_on:
        nwires = lanes * 8 + pmax
        bit_iota = lax.broadcasted_iota(jnp.int32, (1, 1, 8), 2)

        def _wire_bits(arr):  # (T, L) bytes -> (T, L*8) bits, LSB first
            bits = (arr[:, :, None] >> bit_iota) & 1
            return bits.reshape(arr.shape[0], arr.shape[1] * 8)

        rmask = (row_idx < valid).astype(jnp.int32)  # (rows, 1) levels
        # the boundary INTO local row i toggles inside row i's window
        bwin = (
            start_row + lax.broadcasted_iota(jnp.int32, (rows - 1, 1), 0) + 1
        ) // window_rows
        win_iota = lax.broadcasted_iota(
            jnp.int32, (rows - 1, num_windows), 1
        )
        win_onehot = (bwin == win_iota).astype(jnp.float32)

        def _scatter(toggles):  # (rows-1, W) 0/1 -> (NW, W) window counts
            return lax.dot_general(
                win_onehot,
                toggles.astype(jnp.float32),
                dimension_numbers=(((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).astype(jnp.int32)

        acts, ones_rows = [], []

    def _last_valid(arr):  # (rows, L) -> (L,): the row at index valid-1
        onehot = (row_idx == valid - 1).astype(jnp.int32)
        return (arr * onehot).sum(axis=0)

    def _flit(values, ln):
        if pack == "lane":
            return values.reshape(bp, ln, flits).transpose(0, 2, 1)
        return values.reshape(bp, flits, ln)

    # --- popcount stage: ONCE per block, shared by every bucketing
    # (computed lazily — identity-ordering launches skip it entirely) ---
    pc = None

    # --- one reordered + packed stream per unique ordering ---
    streams: dict[Variant, jax.Array] = {}
    emitted = None  # (order, rank, stream) of configs[0] in emit_stream mode
    for cfg in configs:
        if cfg.ordering in streams:
            continue
        key_name, k, descending = cfg.ordering
        order = rank = None
        if key_name in ("acc", "app"):
            # --- bucket encoder + shared rank machinery (psu.py) ---
            if pc is None:
                pc = _popcount_bits(x, width)
            if key_name == "acc":
                key, nb = pc, width + 1
            else:
                key, nb = (pc * k) // (width + 1), k
            if descending:
                key = (nb - 1) - key
            rank = _rank_from_keys(key, nb)
            # --- reorder: one permutation-matrix MXU product yields the
            # ordered payloads (and, in emit_stream mode, `order` = the
            # permuted iota) in a single contraction (DESIGN.md §3.2) ---
            iota_j = lax.broadcasted_iota(jnp.int32, (bp, n, n), 2)
            perm = (rank[:, :, None] == iota_j).astype(jnp.float32)
            rows_payload = [x, w]
            if emit_stream:
                iota_i = lax.broadcasted_iota(jnp.int32, (bp, n), 1)
                rows_payload = [iota_i, x, w]
            payload = jnp.stack(rows_payload, axis=1).astype(jnp.float32)
            moved = lax.dot_general(
                payload,
                perm,
                dimension_numbers=(((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32,
            ).astype(jnp.int32)  # (BP, 2|3, N)
            xs, ws = moved[:, -2, :], moved[:, -1, :]
            if emit_stream:
                order = moved[:, 0, :]
        elif key_name == "column_major":
            # fixed layout permutation — output position (l*F + f) carries
            # input element (f*L + l): a transpose of the (F, L) packet view
            xs = x.reshape(bp, flits, input_lanes).transpose(0, 2, 1)
            xs = xs.reshape(bp, n)
            ws = w.reshape(bp, flits, input_lanes).transpose(0, 2, 1)
            ws = ws.reshape(bp, n)
        else:  # 'none'
            xs, ws = x, w
        if weight_lanes:
            flit_block = jnp.concatenate(
                [_flit(xs, input_lanes), _flit(ws, weight_lanes)], axis=-1
            )
        else:
            flit_block = _flit(xs, input_lanes)
        stream = flit_block.reshape(rows, lanes)
        streams[cfg.ordering] = stream
        if emit_stream and cfg.ordering == configs[0].ordering:
            emitted = (order, rank, stream)

    # --- codec + BT-accumulate per config on the shared streams ---
    bts, edge_rows, inv_rows = [], [], []
    for cfg in configs:
        stream = streams[cfg.ordering]
        zero_inv = jnp.zeros((2, 2, pmax), jnp.int32)

        if cfg.codec in ("none", "gray", "sign_magnitude"):
            if cfg.codec == "gray":
                wire = gray_encode_bytes(stream)
            elif cfg.codec == "sign_magnitude":
                wire = sign_magnitude_encode_bytes(stream)
            else:
                wire = stream
            flips = _popcount_bits(wire[1:] ^ wire[:-1], 8) * bmask
            row = jnp.stack(
                [
                    flips[:, :split_lanes].sum(),
                    flips[:, split_lanes:].sum()
                    if split_lanes < lanes
                    else jnp.int32(0),
                    jnp.int32(0),
                ]
            )
            part = jnp.broadcast_to(row, (2, 1, 3))
            edge = jnp.stack([wire[0], _last_valid(wire)])  # (2, lanes)
            bts.append(jnp.pad(part, ((0, 0), (0, pmax - 1), (0, 0))))
            edge_rows.append(jnp.broadcast_to(edge, (2, 2, lanes)))
            inv_rows.append(zero_inv)
            if act_on:
                tb = _wire_bits(wire[1:] ^ wire[:-1]) * bmask
                act = jnp.pad(_scatter(tb), ((0, 0), (0, pmax)))
                acts.append(jnp.broadcast_to(act, (2, num_windows, nwires)))
                ones_w = (_wire_bits(wire) * rmask).sum(axis=0)
                ones_rows.append(jnp.broadcast_to(
                    jnp.pad(ones_w, (0, pmax)), (2, nwires)
                ))

        elif cfg.codec == "transition":
            # wire_t ^ wire_{t-1} == data_t: boundary flips = data popcount
            ppc = _popcount_bits(stream, 8)
            contrib = ppc[1:] * bmask
            row = jnp.stack(
                [
                    contrib[:, :split_lanes].sum(),
                    contrib[:, split_lanes:].sum()
                    if split_lanes < lanes
                    else jnp.int32(0),
                    jnp.int32(0),
                ]
            )
            part = jnp.broadcast_to(row, (2, 1, 3))
            # edges carry DATA flits (the wrapper adds first-flit popcounts)
            edge = jnp.stack([stream[0], _last_valid(stream)])
            bts.append(jnp.pad(part, ((0, 0), (0, pmax - 1), (0, 0))))
            edge_rows.append(jnp.broadcast_to(edge, (2, 2, lanes)))
            inv_rows.append(zero_inv)
            if act_on:
                # wire-bit toggle at boundary t == data bit of row t
                tb = _wire_bits(stream[1:]) * bmask
                act = jnp.pad(_scatter(tb), ((0, 0), (0, pmax)))
                acts.append(jnp.broadcast_to(act, (2, num_windows, nwires)))
                # the wire LEVEL is the running data parity; slot 0 = time
                # at 1 for a parity-0 entry, slot 1 = this block's parity
                # (the wrapper flips slot 0 per the carried entry parity)
                db = _wire_bits(stream) * rmask
                par = jnp.cumsum(db, axis=0) & 1
                ones_rows.append(jnp.stack([
                    jnp.pad((par * rmask).sum(axis=0), (0, pmax)),
                    jnp.pad(db.sum(axis=0) & 1, (0, pmax)),
                ]))

        else:  # bus_invert
            npart, pw = _partitions(lanes, cfg.partition)
            lbits = 8 * pw
            d = stream.reshape(rows, npart, pw)
            dpc = _popcount_bits(d[1:] ^ d[:-1], 8)  # (rows-1, npart, pw)
            v0, v1 = _bus_invert_bits(dpc.sum(axis=-1), lbits)
            # input/weight lane split inside each partition: global lane id
            # part*pw + j < split_lanes (iota, not a captured constant)
            lane_id = lax.broadcasted_iota(
                jnp.int32, (npart, pw), 0
            ) * pw + lax.broadcasted_iota(jnp.int32, (npart, pw), 1)
            in_mask = (lane_id < split_lanes).astype(jnp.int32)
            parts, edges, inv_edges = [], [], []
            acts_b, ones_b = [], []
            if act_on:
                dxr = (d[1:] ^ d[:-1]).reshape(rows - 1, lanes)
            for v in (v0, v1):
                e = v[1:] ^ v[:-1]  # (rows-1, npart) invert-line flips
                lane_flips = jnp.where(e[:, :, None] == 1, 8 - dpc, dpc)
                lane_flips = lane_flips * bmask[:, :, None]
                bt_in = (lane_flips * in_mask).sum(axis=(0, 2))
                bt_wg = (lane_flips * (1 - in_mask)).sum(axis=(0, 2))
                aux = (e * bmask).sum(axis=0)
                parts.append(jnp.stack([bt_in, bt_wg, aux], axis=-1))
                wire = (d ^ (v[:, :, None] * 0xFF)).reshape(rows, lanes)
                edges.append(jnp.stack([wire[0], _last_valid(wire)]))
                inv_edges.append(jnp.stack([v[0], _last_valid(v)]))
                if act_on:
                    # wire-bit toggle = data-bit toggle XOR its partition's
                    # invert-line flip; the invert line itself is a wire
                    erep = jnp.broadcast_to(
                        e[:, :, None], (rows - 1, npart, pw * 8)
                    ).reshape(rows - 1, lanes * 8)
                    tb = (_wire_bits(dxr) ^ erep) * bmask
                    aux_t = jnp.pad(e * bmask, ((0, 0), (0, pmax - npart)))
                    acts_b.append(
                        _scatter(jnp.concatenate([tb, aux_t], axis=1))
                    )
                    ones_b.append(jnp.concatenate([
                        (_wire_bits(wire) * rmask).sum(axis=0),
                        jnp.pad((v * rmask).sum(axis=0), (0, pmax - npart)),
                    ]))
            bts.append(jnp.pad(
                jnp.stack(parts), ((0, 0), (0, pmax - npart), (0, 0))
            ))
            edge_rows.append(jnp.stack(edges))
            inv_rows.append(jnp.pad(
                jnp.stack(inv_edges), ((0, 0), (0, 0), (0, pmax - npart))
            ))
            if act_on:
                acts.append(jnp.stack(acts_b))
                ones_rows.append(jnp.stack(ones_b))

    out = (jnp.stack(bts), jnp.stack(edge_rows), jnp.stack(inv_rows))
    if act_on:
        out = out + (jnp.stack(acts), jnp.stack(ones_rows))
    return out + emitted if emit_stream else out


def _bt_axes_kernel(*refs, **static):
    """Pallas grid body: one (link, packet-block) cell via ``_axes_block``."""
    activity = static.get("num_windows", 0) > 0
    base_ref = order_ref = rank_ref = stream_ref = act_ref = ones_ref = None
    if activity:
        (x_ref, w_ref, valid_ref, base_ref,
         bt_ref, edge_ref, inv_edge_ref, act_ref, ones_ref) = refs
    elif static["emit_stream"]:
        (x_ref, w_ref, valid_ref, bt_ref, edge_ref, inv_edge_ref,
         order_ref, rank_ref, stream_ref) = refs
    else:
        x_ref, w_ref, valid_ref, bt_ref, edge_ref, inv_edge_ref = refs
    bp, n = x_ref.shape[1:]
    flits = n // static["input_lanes"]
    rows = jnp.int32(bp * flits)
    remaining = valid_ref[0, 0] * flits - pl.program_id(1) * rows
    start = base_ref[0, 0] + pl.program_id(1) * rows if activity else None
    out = _axes_block(x_ref[0], w_ref[0], remaining, start, **static)
    bt_ref[0, 0] = out[0]
    edge_ref[0, 0] = out[1]
    inv_edge_ref[0, 0] = out[2]
    if activity:
        act_ref[0, 0] = out[3]
        ones_ref[0, 0] = out[4]
    if static["emit_stream"]:
        order_ref[0], rank_ref[0], stream_ref[0] = out[3:]


def bt_axes_pallas(
    inputs: jax.Array,
    weights: jax.Array,
    valid: jax.Array,
    *,
    configs: tuple[CodecVariant, ...],
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int = 0,
    split_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    emit_stream: bool = False,
    interpret: bool | None = None,
    window_rows: int = 0,
    num_windows: int = 0,
    base_row: jax.Array | None = None,
):
    """Per-(link, config) coded BT partials of a (L, P, N) batch, ONE launch.

    Args:
      inputs / weights: (L, P, N) int packets; P a multiple of
        ``block_packets`` (the ``ops.py`` wrappers zero-pad; padded rows
        are masked in-kernel via ``valid``).
      valid: (L,) int32 real packet count per link (rows past it are
        masked: zero data BT, zero aux BT).
      configs: static tuple of :class:`CodecVariant` configurations — the
        variant x codec axes of the launch.
      split_lanes: byte lane where the input side ends for the per-side BT
        accounting (default ``input_lanes``; the per-link NoC path packs
        pre-assembled flit rows as N = lanes packets and splits here).
      emit_stream: also emit (order, rank, stream) for ``configs[0]``'s
        ordering — the fused-TX-pipeline mode (requires exactly one config
        with an 'acc'/'app' ordering).
      window_rows / num_windows: static activity-window length in flit
        rows and total (global) window count; ``num_windows > 0`` enables
        the per-wire activity outputs (DESIGN.md §15; incompatible with
        ``emit_stream``).
      base_row: int32 scalar — global flit-row index of this launch's
        first row (chunked streaming offsets it per chunk; default 0).

    Returns:
      (partials, edges, inv_edges[, order, rank, stream]):
        * int32 (L, G, C, 2, PMAX, 3) per-block, per-entry-branch,
          per-partition (input, weight, invert-line) BT partials over
          block-internal valid boundaries (branches are identical for
          every codec except bus-invert; non-partitioned codecs use
          slot 0);
        * int32 (L, G, C, 2, 2, lanes) per-branch first/last-valid wire
          rows (DATA rows for 'transition');
        * int32 (L, G, C, 2, 2, PMAX) per-branch first/last-valid
          invert-line states (bus-invert only, zeros otherwise);
        * with activity: int32 (L, G, C, 2, NW, WIRES) per-branch window
          toggles and (L, G, C, 2, WIRES) per-branch wire-level 1-counts
          (DESIGN.md §15);
        * with ``emit_stream``: int32 (L, P, N) order, (L, P, N) rank and
          (L, P*F, lanes) packed stream.
    """
    configs, split_lanes = _validate_axes_call(
        inputs, valid, configs=configs, width=width, input_lanes=input_lanes,
        weight_lanes=weight_lanes, split_lanes=split_lanes, pack=pack,
        block_packets=block_packets, emit_stream=emit_stream,
        num_windows=num_windows, window_rows=window_rows,
    )
    if interpret is None:
        interpret = default_backend() != "pallas"
    links, p, n = inputs.shape
    lanes = input_lanes + weight_lanes
    nc = len(configs)
    flits = n // input_lanes
    pmax = max_partitions(configs, lanes)
    gblocks = p // block_packets
    activity = num_windows > 0
    grid = (links, gblocks)
    kern = functools.partial(
        _bt_axes_kernel,
        configs=configs,
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        split_lanes=split_lanes,
        pack=pack,
        pmax=pmax,
        emit_stream=emit_stream,
        window_rows=window_rows,
        num_windows=num_windows,
    )
    pk_spec = pl.BlockSpec((1, block_packets, n), lambda l, g: (l, g, 0))
    in_specs = [
        pk_spec,
        pk_spec,
        pl.BlockSpec((1, 1), lambda l, g: (l, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((links, gblocks, nc, 2, pmax, 3), jnp.int32),
        jax.ShapeDtypeStruct((links, gblocks, nc, 2, 2, lanes), jnp.int32),
        jax.ShapeDtypeStruct((links, gblocks, nc, 2, 2, pmax), jnp.int32),
    ]
    out_specs = [
        pl.BlockSpec((1, 1, nc, 2, pmax, 3), lambda l, g: (l, g, 0, 0, 0, 0)),
        pl.BlockSpec((1, 1, nc, 2, 2, lanes), lambda l, g: (l, g, 0, 0, 0, 0)),
        pl.BlockSpec((1, 1, nc, 2, 2, pmax), lambda l, g: (l, g, 0, 0, 0, 0)),
    ]
    if activity:
        nwires = lanes * 8 + pmax
        in_specs.append(pl.BlockSpec((1, 1), lambda l, g: (0, 0)))
        out_shape += [
            jax.ShapeDtypeStruct(
                (links, gblocks, nc, 2, num_windows, nwires), jnp.int32
            ),
            jax.ShapeDtypeStruct((links, gblocks, nc, 2, nwires), jnp.int32),
        ]
        out_specs += [
            pl.BlockSpec(
                (1, 1, nc, 2, num_windows, nwires),
                lambda l, g: (l, g, 0, 0, 0, 0),
            ),
            pl.BlockSpec((1, 1, nc, 2, nwires), lambda l, g: (l, g, 0, 0, 0)),
        ]
    if emit_stream:
        out_shape += [
            jax.ShapeDtypeStruct((links, p, n), jnp.int32),
            jax.ShapeDtypeStruct((links, p, n), jnp.int32),
            jax.ShapeDtypeStruct((links, p * flits, lanes), jnp.int32),
        ]
        out_specs += [
            pk_spec,
            pk_spec,
            pl.BlockSpec(
                (1, block_packets * flits, lanes), lambda l, g: (l, g, 0)
            ),
        ]
    args = [
        inputs.astype(jnp.int32),
        weights.astype(jnp.int32),
        valid.astype(jnp.int32).reshape(links, 1),
    ]
    if activity:
        base = jnp.int32(0) if base_row is None else base_row
        args.append(jnp.asarray(base, jnp.int32).reshape(1, 1))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)


def _validate_axes_call(
    inputs,
    valid,
    *,
    configs,
    width,
    input_lanes,
    weight_lanes,
    split_lanes,
    pack,
    block_packets,
    emit_stream,
    num_windows=0,
    window_rows=0,
):
    """The multi-axis launch contract, shared by every backend."""
    links, p, n = inputs.shape
    lanes = input_lanes + weight_lanes
    configs = validate_codec_variants(configs, width, lanes)
    if p % block_packets != 0:
        raise ValueError(f"P={p} not a multiple of block_packets={block_packets}")
    if n % input_lanes != 0:
        raise ValueError(f"packet size {n} not divisible by input_lanes={input_lanes}")
    if weight_lanes not in (0, input_lanes):
        raise ValueError(
            "the multi-axis kernel needs a symmetric (or absent) weight "
            f"side: weight_lanes={weight_lanes} vs input_lanes={input_lanes}"
        )
    if pack not in ("lane", "row"):
        raise ValueError(f"multi-axis kernel supports pack 'lane'|'row', got {pack!r}")
    if split_lanes is None:
        split_lanes = input_lanes
    if not 0 <= split_lanes <= lanes:
        raise ValueError(f"split_lanes={split_lanes} outside the {lanes}-lane flit")
    if num_windows > 0:
        if window_rows < 1:
            raise ValueError(
                f"activity needs window_rows >= 1, got {window_rows}"
            )
        if emit_stream:
            raise ValueError("activity and emit_stream are exclusive modes")
    if emit_stream:
        if len(configs) != 1 or configs[0].codec != "none":
            raise ValueError(
                "emit_stream needs exactly one uncoded config, got "
                f"{configs}"
            )
        if configs[0].key not in ("acc", "app"):
            raise ValueError(
                "emit_stream needs an 'acc'/'app' ordering (the fused TX "
                f"pipeline), got {configs[0].key!r}"
            )
    if valid.shape != (links,):
        raise ValueError(f"valid must be ({links},), got {tuple(valid.shape)}")
    return configs, split_lanes


def bt_axes_compiled(
    inputs: jax.Array,
    weights: jax.Array,
    valid: jax.Array,
    *,
    configs: tuple[CodecVariant, ...],
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int = 0,
    split_lanes: int | None = None,
    pack: str = "lane",
    block_packets: int = 64,
    emit_stream: bool = False,
    window_rows: int = 0,
    num_windows: int = 0,
    base_row: jax.Array | None = None,
):
    """The compiled (pure-jnp) backend of the multi-axis measurement.

    Same contract, arguments and outputs as :func:`bt_axes_pallas`, but the
    block math runs as ordinary XLA: ``vmap`` over the link axis,
    ``lax.map`` over packet blocks (sequential, so the per-block
    permutation/one-hot intermediates never materialize for more than one
    block — the same VMEM discipline the kernel's grid gives for free).
    Because both backends execute the SAME ``_axes_block`` trace, they are
    bit-exact; ``tests/test_backends.py`` pins it per entry point.
    """
    configs, split_lanes = _validate_axes_call(
        inputs, valid, configs=configs, width=width, input_lanes=input_lanes,
        weight_lanes=weight_lanes, split_lanes=split_lanes, pack=pack,
        block_packets=block_packets, emit_stream=emit_stream,
        num_windows=num_windows, window_rows=window_rows,
    )
    links, p, n = inputs.shape
    lanes = input_lanes + weight_lanes
    flits = n // input_lanes
    pmax = max_partitions(configs, lanes)
    gblocks = p // block_packets
    rows = block_packets * flits
    activity = num_windows > 0
    block = functools.partial(
        _axes_block,
        configs=configs,
        width=width,
        input_lanes=input_lanes,
        weight_lanes=weight_lanes,
        split_lanes=split_lanes,
        pack=pack,
        pmax=pmax,
        emit_stream=emit_stream,
        window_rows=window_rows,
        num_windows=num_windows,
    )
    xb = jnp.moveaxis(
        inputs.astype(jnp.int32).reshape(links, gblocks, block_packets, n), 1, 0
    )
    wb = jnp.moveaxis(
        weights.astype(jnp.int32).reshape(links, gblocks, block_packets, n), 1, 0
    )
    remaining = (
        valid.astype(jnp.int32)[None, :] * flits
        - jnp.arange(gblocks, dtype=jnp.int32)[:, None] * rows
    )  # (G, L)
    if activity:
        base = jnp.int32(0) if base_row is None else base_row
        starts = (
            jnp.asarray(base, jnp.int32)
            + jnp.arange(gblocks, dtype=jnp.int32) * rows
        )  # (G,)
        per_block = jax.vmap(block, in_axes=(0, 0, 0, None))
        outs = lax.map(
            lambda args: per_block(*args), (xb, wb, remaining, starts)
        )
    else:
        per_block = jax.vmap(block)  # over the link axis
        outs = lax.map(lambda args: per_block(*args), (xb, wb, remaining))
    bt, edge, inv = (jnp.moveaxis(o, 1, 0) for o in outs[:3])  # (L, G, ...)
    if activity:
        act, ones = (jnp.moveaxis(o, 1, 0) for o in outs[3:5])
        return bt, edge, inv, act, ones
    if not emit_stream:
        return bt, edge, inv
    order, rank, stream = (jnp.moveaxis(o, 1, 0) for o in outs[3:])
    return (
        bt,
        edge,
        inv,
        order.reshape(links, p, n),
        rank.reshape(links, p, n),
        stream.reshape(links, p * flits, lanes),
    )
