"""Pallas TPU kernel: the popcount-sorting unit (ACC-PSU / APP-PSU).

One grid step sorts a *block of packets* resident in VMEM, reproducing the
hardware dataflow of Fig. 1 stage-for-stage on TPU vector units
(DESIGN.md §3):

  popcount stage   -> bit-twiddling on int32 lanes (VPU), replacing the
                      4-bit LUT + adder tree,
  bucket encoder   -> integer multiply/divide (APP only; compiled away for
                      ACC exactly as the paper's synthesis prunes the LUT),
  one-hot + histogram + prefix sum -> lane cumsums over a (BP, N, K) one-hot
                      tensor (the hardware prefix-sum stage is literally the
                      cumsum over the bucket axis),
  index mapping    -> rank = starts[key] + #earlier-equal, then the scatter
                      SRAM write becomes a one-hot compare + weighted sum
                      (MXU/VPU-friendly; no random-access writes).

Block shapes: packets are (BP, N) int32 in VMEM; the (BP, N, K) and
(BP, N, N) intermediates bound VMEM use, so BP defaults to 64 packets
(N=64, K<=9: ~3.3 MB of int32 temporaries, well inside a v5e core's VMEM).
On real TPU the N axis should be padded to the 128-lane boundary; the
wrapper in ``ops.py`` does this transparently.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .backend import default_backend

__all__ = ["psu_sort_pallas", "psu_sort_compiled"]


def _popcount_bits(x: jax.Array, width: int) -> jax.Array:
    """Branch-free popcount of the low ``width`` bits of int32 lanes.

    SWAR bit-twiddling; valid for width <= 16 (paper uses W=8).  This is the
    VPU replacement for the hardware 4-bit-LUT + adder tree.
    """
    mask = jnp.int32((1 << width) - 1)
    v = x & mask
    v = v - ((v >> 1) & jnp.int32(0x55555555))
    v = (v & jnp.int32(0x33333333)) + ((v >> 2) & jnp.int32(0x33333333))
    v = (v + (v >> 4)) & jnp.int32(0x0F0F0F0F)
    if width > 8:
        v = v + (v >> 8)
    return v & jnp.int32(0x1F)


def _rank_from_keys(key: jax.Array, nb: int) -> jax.Array:
    """Stages 2-3 of the PSU on one (BP, N) int32 key block: one-hot /
    histogram / prefix-sum, then index mapping.

    Factored out of :func:`_rank_block` so the multi-axis BT kernel
    (``axes.py``) can derive several bucketings from ONE popcount pass
    without duplicating the counting-sort machinery.  Returns the
    (BP, N) int32 ``rank`` (stable counting-sort output addresses).
    """
    bp, n = key.shape

    # --- one-hot / histogram / prefix-sum stages ---
    iota_k = lax.broadcasted_iota(jnp.int32, (bp, n, nb), 2)
    onehot = (key[:, :, None] == iota_k).astype(jnp.int32)  # (BP, N, K)
    within = jnp.cumsum(onehot, axis=1) - onehot  # earlier-equal count
    hist = onehot.sum(axis=1)  # (BP, K)
    starts = jnp.cumsum(hist, axis=1) - hist  # exclusive prefix sum

    # --- index mapping stage ---
    return ((within + starts[:, None, :]) * onehot).sum(axis=2)  # (BP, N)


def _rank_block(
    x: jax.Array, *, width: int, k: int | None, descending: bool
) -> jax.Array:
    """Stages 1-3 of the PSU on one (BP, N) int32 block: popcount (+ APP
    bucket encoder), one-hot / histogram / prefix-sum, index mapping.

    Shared between the standalone sort kernel below and the multi-axis BT
    core (``axes.py``), so the key derivation cannot drift between them.
    Returns the (BP, N) int32 ``rank`` (stable counting-sort output
    addresses).
    """
    # --- popcount stage (+ APP bucket encoder) ---
    p = _popcount_bits(x, width)
    if k is None:
        key, nb = p, width + 1
    else:
        key, nb = (p * k) // (width + 1), k
    if descending:
        key = (nb - 1) - key
    return _rank_from_keys(key, nb)


def _psu_kernel(
    x_ref, order_ref, rank_ref, *, width: int, k: int | None, descending: bool
):
    """Sort one (BP, N) block of packets by (approximate) popcount."""
    x = x_ref[...].astype(jnp.int32)
    bp, n = x.shape
    rank = _rank_block(x, width=width, k=k, descending=descending)

    # scatter as one-hot compare + weighted sum: order[j] = i s.t. rank_i = j
    iota_j = lax.broadcasted_iota(jnp.int32, (bp, n, n), 2)
    iota_i = lax.broadcasted_iota(jnp.int32, (bp, n, n), 1)
    sel = (rank[:, :, None] == iota_j).astype(jnp.int32)
    order = (sel * iota_i).sum(axis=1)  # (BP, N)

    order_ref[...] = order
    rank_ref[...] = rank


def psu_sort_pallas(
    packets: jax.Array,
    *,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    block_packets: int = 64,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Sort indices for a batch of packets with the PSU kernel.

    Args:
      packets: (P, N) integer array; P must be a multiple of
        ``block_packets`` (use the ``ops.py`` wrapper for padding).
      width: element bit width W.
      k: APP bucket count, or ``None`` for the exact ACC unit.
      descending: sort high-popcount-first (paper Fig. 2 streams a
        decreasing trend).
      block_packets: packets per grid step (VMEM block height).
      interpret: run the kernel body in Python (CPU validation mode).

    Returns:
      (order, rank) int32 arrays of shape (P, N).
    """
    if interpret is None:
        interpret = default_backend() != "pallas"
    p, n = packets.shape
    if p % block_packets != 0:
        raise ValueError(f"P={p} not a multiple of block_packets={block_packets}")
    grid = (p // block_packets,)
    kern = functools.partial(_psu_kernel, width=width, k=k, descending=descending)
    out_shape = [
        jax.ShapeDtypeStruct((p, n), jnp.int32),
        jax.ShapeDtypeStruct((p, n), jnp.int32),
    ]
    spec = pl.BlockSpec((block_packets, n), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(packets.astype(jnp.int32))


def psu_sort_compiled(
    packets: jax.Array,
    *,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """The compiled (pure-jnp) backend of the PSU sort.

    Runs the SAME rank derivation as the kernel (:func:`_rank_block`) on
    the whole (P, N) batch at once — every stage is per-packet, so block
    granularity cannot change results — and inverts the rank permutation
    with an argsort instead of the kernel's one-hot scatter (identical
    output on a permutation).  Bit-exact with the kernel.
    """
    rank = _rank_block(
        packets.astype(jnp.int32), width=width, k=k, descending=descending
    )
    order = jnp.argsort(rank, axis=-1).astype(jnp.int32)
    return order, rank
