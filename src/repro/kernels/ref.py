"""Pure-jnp oracles for the Pallas kernels.

Each function mirrors one kernel in this package with straightforward
``jnp`` code; kernel tests sweep shapes/dtypes and ``assert_allclose``
against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.popcount import bucket_map, popcount
from repro.core.sorting import counting_sort_indices, counting_sort_ranks

__all__ = [
    "psu_sort_ref",
    "psu_stream_ref",
    "bt_count_ref",
    "bt_variants_ref",
    "variant_order_ref",
    "codec_stream_ref",
    "bt_codecs_ref",
    "quantize_egress_ref",
]


def psu_sort_ref(
    packets: jax.Array, width: int = 8, k: int | None = None, descending: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the PSU kernel.

    Args:
      packets: (P, N) integer payloads.
      k: APP bucket count; ``None`` = exact (ACC).

    Returns:
      (order, rank): both (P, N) int32.  ``order[p, j]`` is the input index
      transmitted j-th; ``rank[p, i]`` is the output slot of input element i.
    """
    keys = popcount(packets, width)
    nb = width + 1
    if k is not None:
        keys = bucket_map(keys, width, k)
        nb = k
    if descending:
        keys = (nb - 1) - keys
    rank = counting_sort_ranks(keys, nb)
    order = counting_sort_indices(keys, nb)
    return order.astype(jnp.int32), rank.astype(jnp.int32)


def psu_stream_ref(
    inputs: jax.Array,
    weights: jax.Array | None = None,
    width: int = 8,
    k: int | None = None,
    descending: bool = False,
    input_lanes: int = 8,
    weight_lanes: int | None = None,
    pack: str = "lane",
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused TX-pipeline kernel: the unfused composition
    ``psu_sort_ref`` -> gather -> flit-pack -> ``bt_count_ref`` per side.

    Keeps the one-hot scatter formulation (via ``counting_sort_indices``)
    that the fused kernel replaced, exactly so tests can pin the fused path
    against it bit-for-bit.

    Returns (order, rank, stream, bt_input, bt_weight) matching
    ``repro.kernels.psu_stream``.
    """
    if weights is None:
        weight_lanes = 0 if weight_lanes is None else weight_lanes
        weights = jnp.zeros_like(inputs)
    elif weight_lanes is None:
        weight_lanes = input_lanes
    order, rank = psu_sort_ref(inputs, width=width, k=k, descending=descending)
    p, n = inputs.shape
    flits = n // input_lanes

    def _flits(values, lanes):
        if pack == "lane":
            return values.reshape(p, lanes, flits).transpose(0, 2, 1)
        return values.reshape(p, flits, lanes)

    xs = jnp.take_along_axis(inputs.astype(jnp.int32), order, axis=-1)
    halves = [_flits(xs, input_lanes)]
    if weight_lanes:
        ws = jnp.take_along_axis(weights.astype(jnp.int32), order, axis=-1)
        halves.append(_flits(ws, weight_lanes))
    stream = jnp.concatenate(halves, axis=-1).reshape(
        p * flits, input_lanes + weight_lanes
    )
    bt_i = bt_count_ref(stream[:, :input_lanes])
    bt_w = (
        bt_count_ref(stream[:, input_lanes:]) if weight_lanes else jnp.int32(0)
    )
    return order, rank, stream.astype(jnp.uint8), bt_i, bt_w


def variant_order_ref(
    values: jax.Array,
    variant,
    *,
    width: int = 8,
    input_lanes: int = 8,
) -> jax.Array:
    """Transmit order of one BT-variant — the per-variant reorder applied by
    the ``bt_variants`` kernel, as a pure-jnp (P, N) permutation.

    ``variant`` is a ``(key, k, descending)`` triple
    (``repro.kernels.Variant``).  Built only from
    ``repro.core`` primitives so the kernel tests pin against the paper's
    reference dataflow.
    """
    key_name, k, descending = variant
    p, n = values.shape
    if key_name == "none":
        order = jnp.arange(n, dtype=jnp.int32)
        return jnp.broadcast_to(order, (p, n))
    if key_name == "column_major":
        flits = n // input_lanes
        j = jnp.arange(n, dtype=jnp.int32)
        order = (j % flits) * input_lanes + j // flits
        return jnp.broadcast_to(order, (p, n))
    keys = popcount(values, width)
    nb = width + 1
    if key_name == "app":
        keys = bucket_map(keys, width, k)
        nb = k
    if descending:
        keys = (nb - 1) - keys
    return counting_sort_indices(keys, nb).astype(jnp.int32)


def bt_variants_ref(
    inputs: jax.Array,
    weights: jax.Array | None,
    variants,
    *,
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int = 0,
    pack: str = "lane",
) -> jax.Array:
    """Oracle for the multi-variant BT kernel: for each variant, the unfused
    order -> gather -> flit-pack -> BT composition on the whole stream.

    Returns int32 (V, 2) per-variant (input-side, weight-side) totals,
    matching ``repro.kernels.bt_count_variants``.
    """
    p, n = inputs.shape
    flits = n // input_lanes

    def _flits(values, lanes):
        if pack == "lane":
            return values.reshape(p, lanes, flits).transpose(0, 2, 1)
        return values.reshape(p, flits, lanes)

    rows = []
    for variant in variants:
        order = variant_order_ref(
            inputs, variant, width=width, input_lanes=input_lanes
        )
        xs = jnp.take_along_axis(inputs.astype(jnp.int32), order, axis=-1)
        halves = [_flits(xs, input_lanes)]
        if weight_lanes:
            ws = jnp.take_along_axis(weights.astype(jnp.int32), order, axis=-1)
            halves.append(_flits(ws, weight_lanes))
        stream = jnp.concatenate(halves, axis=-1).reshape(
            p * flits, input_lanes + weight_lanes
        )
        bt_i = bt_count_ref(stream[:, :input_lanes])
        bt_w = (
            bt_count_ref(stream[:, input_lanes:])
            if weight_lanes
            else jnp.int32(0)
        )
        rows.append(jnp.stack([bt_i, bt_w]))
    return jnp.stack(rows).astype(jnp.int32)


def codec_stream_ref(stream: jax.Array, scheme: str, partition: int | None = None):
    """The wire image of ``stream`` under one codec scheme — the sequential
    ``repro.codec.schemes`` encoders (bus-invert as a ``lax.scan`` over
    flits), which the prefix-scan formulation inside the codec kernel is
    pinned against.  Returns a ``CodedStream`` (wire, invert lines | None).
    """
    # deferred: repro.codec registers stages into repro.link at import, and
    # repro.link imports this package — a module-level import would cycle
    from repro.codec.schemes import bus_invert_encode, codec_by_name

    if scheme == "bus_invert":
        return bus_invert_encode(stream, partition)
    return codec_by_name(scheme).encode(stream.astype(jnp.uint8))


def bt_codecs_ref(
    inputs: jax.Array,
    weights: jax.Array | None,
    configs,
    *,
    width: int = 8,
    input_lanes: int = 8,
    weight_lanes: int = 0,
    pack: str = "lane",
) -> jax.Array:
    """Oracle for the multi-codec BT kernel: for each (ordering, codec)
    config, the unfused order -> gather -> flit-pack -> codec-encode -> BT
    composition on the whole stream.

    ``configs`` are ``(key, k, descending, codec, partition)`` tuples
    (``repro.kernels.CodecVariant``).  Returns int32 (C, 3)
    per-config (input-side, weight-side, invert-line) totals, matching
    ``repro.kernels.bt_count_codecs``.
    """
    from repro.codec.schemes import invert_line_transitions

    p, n = inputs.shape
    flits = n // input_lanes

    def _flits(values, lanes):
        if pack == "lane":
            return values.reshape(p, lanes, flits).transpose(0, 2, 1)
        return values.reshape(p, flits, lanes)

    rows = []
    for cfg in configs:
        key, k, descending, scheme, partition = cfg
        order = variant_order_ref(
            inputs, (key, k, descending), width=width, input_lanes=input_lanes
        )
        xs = jnp.take_along_axis(inputs.astype(jnp.int32), order, axis=-1)
        halves = [_flits(xs, input_lanes)]
        if weight_lanes:
            ws = jnp.take_along_axis(weights.astype(jnp.int32), order, axis=-1)
            halves.append(_flits(ws, weight_lanes))
        stream = jnp.concatenate(halves, axis=-1).reshape(
            p * flits, input_lanes + weight_lanes
        )
        coded = codec_stream_ref(stream.astype(jnp.uint8), scheme, partition)
        bt_i = bt_count_ref(coded.wire[:, :input_lanes])
        bt_w = (
            bt_count_ref(coded.wire[:, input_lanes:])
            if weight_lanes
            else jnp.int32(0)
        )
        rows.append(jnp.stack([bt_i, bt_w, invert_line_transitions(coded.invert)]))
    return jnp.stack(rows).astype(jnp.int32)


def bt_count_ref(stream: jax.Array, width: int = 8) -> jax.Array:
    """Oracle for the BT-count kernel: total bit transitions of a flit
    stream (T, L)."""
    a = stream.astype(jnp.uint32)
    flips = jnp.bitwise_xor(a[1:], a[:-1])
    return popcount(flips, width).sum().astype(jnp.int32)


def quantize_egress_ref(
    x: jax.Array, block: int = 256
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the int8 egress quantizer (gradient-compression path).

    Per-block symmetric int8 quantization: x is (M,) float32, viewed as
    (M // block, block); scale = max|x| / 127 per block.

    Returns:
      (q, scales): int8 (M,) and float32 (M // block,).
    """
    m = x.shape[0]
    if m % block != 0:
        raise ValueError(f"size {m} not divisible by block {block}")
    xb = x.reshape(m // block, block)
    scale = jnp.max(jnp.abs(xb), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(xb / safe[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(m), scale
