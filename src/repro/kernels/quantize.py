"""Pallas TPU kernel: blockwise int8 egress quantizer.

Used by the compressed gradient all-reduce path (``repro.optim.compress``):
gradients are quantized to symmetric int8 per block before crossing ICI, and
the popcount-ordered egress permutation is applied to the int8 view.  The
kernel fuses abs-max reduction, scale computation and rounding in one VMEM
pass per block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import default_backend

__all__ = ["quantize_egress_pallas", "quantize_egress_compiled"]


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]  # (R, block) float32
    amax = jnp.max(jnp.abs(x), axis=1)  # (R,)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_egress_pallas(
    x: jax.Array,
    *,
    block: int = 256,
    rows_per_step: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Quantize a flat float32 vector to blockwise-symmetric int8.

    Args:
      x: (M,) float32 with M divisible by ``block`` (wrapper pads).

    Returns:
      (q, scales): int8 (M,), float32 (M / block,).
    """
    if interpret is None:
        interpret = default_backend() != "pallas"
    m = x.shape[0]
    if m % block != 0:
        raise ValueError(f"size {m} not divisible by block {block}")
    rows = m // block
    rp = min(rows_per_step, rows)
    if rows % rp != 0:
        rp = 1  # fallback: one row per step (always divides)
    grid = (rows // rp,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rp, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rp, block), lambda i: (i, 0)),
            pl.BlockSpec((rp,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, block), jnp.int8),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=interpret,
    )(x.reshape(rows, block).astype(jnp.float32))
    return q.reshape(m), s


def quantize_egress_compiled(
    x: jax.Array, *, block: int = 256
) -> tuple[jax.Array, jax.Array]:
    """The compiled (pure-jnp) backend: the kernel's abs-max / scale /
    round math as one reshaped pass — same primitives and dtypes, so the
    int8 codes and float32 scales are bit-identical."""
    m = x.shape[0]
    if m % block != 0:
        raise ValueError(f"size {m} not divisible by block {block}")
    rows = m // block
    xr = x.reshape(rows, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xr), axis=1)
    scale = amax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(xr / safe[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(m), scale
