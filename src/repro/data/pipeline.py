"""Deterministic, shardable synthetic LM data pipeline.

Design goals (DESIGN.md §5 fault tolerance):

  * **Deterministic by (seed, step, shard)** — every batch is a pure function
    of those three integers, so restarts resume bit-exactly and stragglers /
    re-scheduled shards regenerate identical data with no coordination.
  * **Shardable** — ``shard_batch(step, shard, num_shards)`` yields that
    shard's slice of the global batch; elastic rescale (num_shards changes)
    re-partitions the same global stream.
  * **Checkpointable** — pipeline state is just the step counter.

The token stream is a noisy affine recurrence (t_{i+1} ~ a*t_i + c + noise),
so models can actually learn it — example training runs show decreasing
loss rather than flat noise.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1  # fraction of positions replaced by uniform noise
    mult: int = 5
    offset: int = 17


class SyntheticLMDataset:
    """Iterator-style access: ``global_batch(step)`` / ``shard_batch(...)``."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _rng(self, step: int, row: int) -> np.random.Generator:
        # independent stream per (seed, step, row): stable under resharding
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, row])
        )

    def _row(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        rng = self._rng(step, row)
        n = cfg.seq_len + 1
        toks = np.empty(n, np.int64)
        toks[0] = rng.integers(0, cfg.vocab)
        noise_mask = rng.random(n) < cfg.noise
        noise_vals = rng.integers(0, cfg.vocab, n)
        for i in range(1, n):
            toks[i] = (toks[i - 1] * cfg.mult + cfg.offset) % cfg.vocab
            if noise_mask[i]:
                toks[i] = noise_vals[i]
        return toks

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        rows = np.stack([self._row(step, r) for r in range(self.cfg.global_batch)])
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
        }

    def shard_batch(self, step: int, shard: int, num_shards: int) -> dict[str, np.ndarray]:
        """This shard's contiguous slice of the global batch."""
        gb = self.cfg.global_batch
        if gb % num_shards:
            raise ValueError(f"global_batch {gb} not divisible by {num_shards} shards")
        per = gb // num_shards
        rows = np.stack(
            [self._row(step, r) for r in range(shard * per, (shard + 1) * per)]
        )
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
        }

    # --- pipeline state (for checkpointing) ---
    @staticmethod
    def state(step: int) -> dict[str, int]:
        return {"step": int(step)}

    @staticmethod
    def restore(state: dict[str, int]) -> int:
        return int(state["step"])
