"""End-to-end training driver: a ~100M-param LM on the synthetic pipeline.

Fault-tolerant by construction: atomic checkpoints + deterministic data; a
killed run resumes bit-exactly (try Ctrl-C mid-run and re-launch).

    PYTHONPATH=src python examples/train_lm.py --steps 300        # full run
    PYTHONPATH=src python examples/train_lm.py --steps 5 --tiny   # smoke

The default config is an internlm2-family decoder (~95M params: 12 layers,
d_model 512, GQA 8/4, d_ff 2048, 92544 vocab tied).  A few hundred steps on
the affine-recurrence corpus drop loss from ~11.5 toward the corpus entropy
floor (CPU: ~30 s/step at this scale; on TPU this config is minutes).

REPRO_BENCH_TINY=1 (the CI examples-smoke contract shared with
``benchmarks/run.py``) forces the --tiny config at a few short steps,
whatever the flags say.
"""

import argparse
import os

from repro.configs import get_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import TrainLoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--tiny", action="store_true", help="toy width (CI smoke)")
    args = ap.parse_args()

    if os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0"):
        args.tiny = True
        args.steps = min(args.steps, 3)
        args.seq, args.batch = 64, 2

    if args.tiny:
        cfg = get_config(
            "internlm2-1.8b", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
            d_ff=128, vocab=512, attn_impl="dense", tie_embeddings=True,
        )
    else:
        cfg = get_config(
            "internlm2-1.8b", n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
            d_ff=2048, tie_embeddings=True, attn_impl="dense",
        )  # ~95M params
    n_params = cfg.param_count()
    print(f"arch: {cfg.name}-derived  params ~{n_params / 1e6:.0f}M")

    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
                      seed=0, noise=0.1)
    # schedule horizon independent of --steps so short runs stay at peak lr
    opt = AdamWConfig(peak_lr=3e-4, warmup_steps=20,
                      total_steps=max(args.steps, 1000))
    loop = TrainLoopConfig(
        steps=args.steps, checkpoint_every=25, checkpoint_dir=args.ckpt,
        log_every=5,
    )
    result = train(cfg, data, opt, loop)
    losses = [m["loss"] for m in result["log"]]
    print(f"loss: first {losses[0]:.3f} -> last {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
