"""The paper's own workload (§IV-B): LeNet conv1 + pool through the PSU
platform, end to end — the allocation unit runs the fused TX pipeline
(``repro.link.TxPipeline``, one Pallas launch per packet block), the
transmitting units reorder (input, weight) pairs, PEs accumulate
order-insensitively, and the link power model converts measured BT into
power savings.

    PYTHONPATH=src python examples/lenet_link_power.py
"""

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.datagen import im2col, synth_images
from repro.core import psu_area
from repro.link import LinkSpec, TxPipeline

KERNEL, ELEMS, LANES = 5, 64, 16


def main() -> None:
    rng = np.random.default_rng(0)
    imgs = synth_images(8, seed=7)
    kern = rng.integers(0, 256, KERNEL * KERNEL, dtype=np.uint8)

    spec = LinkSpec(
        width_bits=8 * LANES,
        flits_per_packet=ELEMS // LANES,
        input_lanes=LANES,
        weight_lanes=0,
    )
    pipes = {
        name: TxPipeline(dataclasses.replace(spec, key=name))
        for name in ("none", "acc", "app")
    }
    model = pipes["none"].power

    bt = {"none": 0, "acc": 0, "app": 0}
    flits_sent = 0
    conv_checksum = {"none": 0, "acc": 0, "app": 0}
    for img in imgs:
        patches = im2col(img, KERNEL)
        w = np.broadcast_to(kern, patches.shape)
        flat_i = patches.reshape(-1)
        flat_w = np.ascontiguousarray(w).reshape(-1)
        p = flat_i.size // ELEMS
        x = jnp.asarray(flat_i[: p * ELEMS].reshape(p, ELEMS))
        wj = jnp.asarray(flat_w[: p * ELEMS].reshape(p, ELEMS))
        for name, pipe in pipes.items():
            res = pipe.run(x)
            bt[name] += int(res.bt_input)
            oi = jnp.take_along_axis(x, res.order, -1)
            ow = jnp.take_along_axis(wj, res.order, -1)
            conv_checksum[name] += int(
                (oi.astype(jnp.int64) * ow.astype(jnp.int64)).sum()
            )
        flits_sent += p * ELEMS // LANES

    assert conv_checksum["none"] == conv_checksum["acc"] == conv_checksum["app"], \
        "accumulation must be order-insensitive"
    print(f"{flits_sent} flits on the 128-bit input link")
    for name in ("acc", "app"):
        red = 1 - bt[name] / bt["none"]
        e0 = model.link_energy_pj(bt["none"], flits_sent)
        e1 = model.link_energy_pj(bt[name], flits_sent)
        print(f"{name.upper():4s}: BT {bt['none']} -> {bt[name]} "
              f"({red * 100:.1f} % BT red, paper: 20.4/19.5) | "
              f"link power red {model.power_reduction(red) * 100:.1f} % "
              f"(paper 18.3/16.5) | modeled energy {e0 / 1e6:.2f} -> "
              f"{e1 / 1e6:.2f} uJ")
    acc_a, app_a = psu_area(25), psu_area(25, k=4)
    print(f"sorting-unit area: ACC {acc_a.total:.0f} um^2, APP {app_a.total:.0f} "
          f"um^2 (-{100 * (1 - app_a.total / acc_a.total):.1f} %, paper -35.4 %)")


if __name__ == "__main__":
    main()
