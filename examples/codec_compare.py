"""Ordering vs coding vs composed: the net-of-overhead BT/power table.

The paper's PSU *orders* transmitted data; the classic alternative *codes*
it (bus-invert et al.).  This example scores precise ACC, APP k=4,
bus-invert alone, and the ordering∘coding compositions on a conv-like
stream — every (ordering, codec) pair measured by ONE
`repro.kernels.bt_count_codecs` launch per stream, every reduction net of
the codec's invert-line transitions, and every codec's extra wires and
encoder area reported next to its win (DESIGN.md §11).

    PYTHONPATH=src python examples/codec_compare.py
"""

from repro.codec import codec_overhead, compare_streams, demo_workloads, format_table
from repro.kernels import Variant
from repro.link import LinkPowerModel

LANES = 16


def main() -> None:
    streams = demo_workloads(images=4)["conv"]
    print(
        f"workload: conv-like, {int(streams[0].shape[0])} packets of "
        f"{int(streams[0].shape[1])} bytes on a {8 * LANES}-bit link"
    )

    rows = compare_streams(
        streams,
        LANES,
        orderings=("none", Variant("acc"), Variant("app", 4)),
        codecs=("none", "bus_invert", "bus_invert4"),
        workload="conv",
    )
    print()
    print(format_table(rows))

    print("\ncodec hardware overhead on this link:")
    for name in ("bus_invert", "bus_invert4", "transition", "gray"):
        ov = codec_overhead(name, LANES)
        print(
            f"  {name:12s} +{ov.extra_wires} wires "
            f"({100 * ov.wire_overhead:.1f}% wider link), "
            f"encoder {ov.encoder_area_um2:.0f} um2"
        )

    power = LinkPowerModel()
    base = next(r for r in rows if r.label == "none")
    best = max(rows, key=lambda r: r.bt_reduction)
    print(
        f"\nbest config: {best.label} — {100 * best.bt_reduction:.2f}% BT"
        f" reduction net of overhead -> "
        f"{100 * power.power_reduction(best.bt_reduction):.2f}% link-related"
        f" power reduction ({base.energy_pj - best.energy_pj:.0f} pJ saved"
        f" on this stream)"
    )


if __name__ == "__main__":
    main()
