"""Design-space exploration: map the paper's area/BT/latency trade-off.

The paper compares two designs (precise ACC-PSU vs APP-PSU k=4); this
example sweeps the whole bucket axis plus the comparator baselines with
`repro.dse`, measures every variant's BT on a conv-like stream in ONE
batched Pallas launch, and prints the Pareto front — the measured knee of
the area x BT plane is the paper's own k=4 choice.

    PYTHONPATH=src python examples/dse_pareto.py
"""

import jax.numpy as jnp
import numpy as np

from repro.dse import (
    AREA_BT_OBJECTIVES,
    DesignPoint,
    Workload,
    evaluate_grid,
    k_sweep,
    knee_point,
    pareto_front,
)


def conv_like_stream(n_images: int = 4, hw: int = 32, kernel: int = 5,
                     elems: int = 64, seed: int = 0) -> np.ndarray:
    """Spatially-correlated im2col packets (a tiny inline stand-in for
    benchmarks/datagen.py, which examples cannot import)."""
    rng = np.random.default_rng(seed)
    imgs = rng.normal(size=(n_images, hw, hw))
    for _ in range(2):  # smooth -> neighboring pixels correlate
        imgs = (imgs + np.roll(imgs, 1, 1) + np.roll(imgs, -1, 1)
                + np.roll(imgs, 1, 2) + np.roll(imgs, -1, 2)) / 5
    thr = np.quantile(imgs, 0.55, axis=(1, 2), keepdims=True)
    v = np.clip(imgs - thr, 0, None)
    v = (v / (v.max(axis=(1, 2), keepdims=True) + 1e-9) * 255).astype(np.uint8)
    out = hw - kernel + 1
    patches = np.lib.stride_tricks.sliding_window_view(
        v, (kernel, kernel), axis=(1, 2)
    ).reshape(n_images * out * out, kernel * kernel)
    flat = patches.reshape(-1)
    return flat[: flat.size // elems * elems].reshape(-1, elems)


def main() -> None:
    stream = conv_like_stream()
    workload = Workload("conv_like", (jnp.asarray(stream),), lanes=16)
    print(f"workload: {stream.shape[0]} packets of {stream.shape[1]} bytes")

    points = k_sweep(n=25, width=8, ks=(2, 3, 4, 6, 8)) + (
        DesignPoint(family="bitonic", k=None, ordering="acc"),
        DesignPoint(family="csn", k=None, ordering="acc"),
    )
    evals = evaluate_grid(points, workload)  # ONE variant-BT launch
    front = pareto_front(evals)  # area x BT-reduction x latency
    plane_front = pareto_front(evals, AREA_BT_OBJECTIVES)
    knee = knee_point(plane_front, AREA_BT_OBJECTIVES)

    print(f"\n{'design':14s} {'area um2':>9s} {'area red':>9s} "
          f"{'BT red':>8s} {'latency':>8s}  front")
    for e in evals:
        mark = "*" if e in front else " "
        knee_mark = "  <- knee (area x BT)" if e is knee else ""
        print(f"{e.label:14s} {e.area_um2:>9.0f} "
              f"{e.area_reduction * 100:>8.1f}% {e.bt_reduction * 100:>7.2f}% "
              f"{e.latency_ns:>6.0f}ns  {mark}{knee_mark}")

    print(f"\n3-objective front: {', '.join(e.label for e in front)}")
    if knee.point.ordering == "app" and knee.point.k == 4:
        note = "the paper's own APP k=4 pick (35.4% area reduction, Fig. 5)"
    else:
        note = ("a point the paper never evaluated — on the canonical "
                "power-of-two sweep k in {2,4,8} the knee is the paper's "
                "k=4 (see benchmarks/dse_sweep.py)")
    print(f"area x BT knee: {knee.label} — {note}")

    # one NoC point: the same design measured per link on a 4x4 mesh
    noc = evaluate_grid(
        (DesignPoint(ordering="app", k=4, topology="mesh4x4"),), workload
    )[0]
    print(f"\nNoC {noc.point.topology}: fabric BT red "
          f"{noc.noc_bt_reduction * 100:.2f}% over {noc.noc_active_links} "
          "links (sort once at the source, savings ride every hop)")


if __name__ == "__main__":
    main()
