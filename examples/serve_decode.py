"""Serving example: batched prefill + decode with the paper's technique on
the serving data path.

Before serving, the contraction axes of every layer are popcount-ordered
(`apply_weight_ordering`) — a numeric no-op verified here by comparing the
generated tokens — and the modeled HBM weight-stream BT saving is reported
via the ``repro.link`` row-stream TX pipeline, with sign-magnitude recoding
(the beyond-paper encoding win).

    PYTHONPATH=src python examples/serve_decode.py

REPRO_BENCH_TINY=1 (the CI examples-smoke contract) caps the batch and
token counts and keeps the smoke config regardless of --full.
"""

import argparse
import dataclasses
import os

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.link import LinkSpec, TxPipeline
from repro.models import init_params
from repro.serve import generate
from repro.traffic import apply_weight_ordering, int8_view


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true",
                    help="serve a ~100M config instead of the smoke config")
    args = ap.parse_args()

    if os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0"):
        args.full = False
        args.batch = min(args.batch, 2)
        args.prompt_len = min(args.prompt_len, 8)
        args.new_tokens = min(args.new_tokens, 4)

    if args.full:
        cfg = get_config("internlm2-1.8b", n_layers=8, d_model=512, n_heads=8,
                         n_kv_heads=4, d_ff=2048, tie_embeddings=True,
                         attn_impl="dense", param_dtype="bfloat16")
    else:
        cfg = smoke_config("internlm2-1.8b")
    params = init_params(cfg, jax.random.key(0))
    ordered = apply_weight_ordering(params, cfg, "app")

    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    out_base = generate(params, cfg, prompts, args.new_tokens)
    out_ord = generate(ordered, cfg, prompts, args.new_tokens)
    same = np.array_equal(np.asarray(out_base.tokens), np.asarray(out_ord.tokens))
    print(f"generated {args.batch}x{args.new_tokens} tokens; "
          f"ordering-invariant: {same}")
    assert same

    print("\nmodeled decode weight-stream BT (per layer-0 tensor):")
    down = int8_view(params["layers"]["mlp"]["down"][0])  # (ff, d) wire image
    spec = LinkSpec(flits_per_packet=1, input_lanes=16, weight_lanes=0,
                    pack="col", k=4)
    for sm in (False, True):
        for strat in ("none", "app"):
            rep = TxPipeline(dataclasses.replace(
                spec,
                key="none" if strat == "none" else "row_bucket",
                encode="sign_magnitude" if sm else "identity",
            )).measure_rows(down, "mlp.down")
            print(f"  sign_magnitude={sm!s:5s} order={strat:4s} "
                  f"BT/flit={rep.overall_bt_per_flit:6.2f}")
    print("(sign-magnitude recoding ~halves BT; ordering adds a few % on "
          "magnitude-structured rows — EXPERIMENTS.md §Arch-BT)")


if __name__ == "__main__":
    main()
