"""Quickstart: the popcount-sorting unit in 60 seconds.

Runs the ACC/APP PSU (Pallas kernel) on a packet of bytes, shows the
Fig.-2-style ordered stream, measures the link-BT saving with the fused
``repro.link.TxPipeline`` (one kernel launch per packet block), and prints
the area model's Fig.-5 numbers.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import bitonic_area, bucket_map, csn_area, popcount, psu_area
from repro.kernels import psu_reorder, psu_sort
from repro.link import LinkSpec, TxPipeline


def main() -> None:
    rng = np.random.default_rng(0)
    packet = jnp.asarray(rng.integers(0, 256, (1, 16), dtype=np.uint8))
    print("input bytes   :", [f"{int(v):02x}" for v in packet[0]])
    print("'1'-bit counts:", np.asarray(popcount(packet))[0].tolist())
    print("APP buckets   :", np.asarray(bucket_map(popcount(packet)))[0].tolist())

    order, rank = psu_sort(packet, k=4)
    print("APP sort order:", np.asarray(order)[0].tolist())
    out = psu_reorder(packet, k=4)
    print("ordered stream:", [f"{int(v):02x}" for v in out[0]],
          "(popcount-bucket monotone, Fig. 2)")

    # Table-I style link measurement on 2000 packets, fused TX pipeline
    spec = LinkSpec()  # paper framing: 128-bit link, 4 flits, 8+8 lanes
    inp = jnp.asarray(rng.integers(0, 256, (2000, spec.elems_per_packet), np.uint8))
    wgt = jnp.asarray(rng.integers(0, 256, (2000, spec.elems_per_packet), np.uint8))
    base = TxPipeline(LinkSpec(key="none")).measure(inp, wgt)
    for strat in ("acc", "app"):
        r = TxPipeline(LinkSpec(key=strat)).measure(inp, wgt)
        print(f"{strat.upper():4s} ordering: {r.overall_bt_per_flit:.2f} "
              f"BT/flit vs {base.overall_bt_per_flit:.2f} "
              f"({r.reduction_vs(base) * 100:.1f} % reduction, "
              f"fused={r.fused})")

    print("\nArea model (22 nm, N=25 window — paper Fig. 5):")
    for name, a in [("Bitonic", bitonic_area(25)), ("CSN", csn_area(25)),
                    ("ACC-PSU", psu_area(25)), ("APP-PSU", psu_area(25, k=4))]:
        print(f"  {name:8s} {a.total:8.0f} um^2 "
              f"(popcount {a.popcount:.0f} + sort {a.sort:.0f})")
    acc, app = psu_area(25), psu_area(25, k=4)
    print(f"  APP vs ACC: -{100 * (1 - app.total / acc.total):.1f} % "
          "(paper: -35.4 %)")


if __name__ == "__main__":
    main()
