"""The sorting unit on a NoC: per-link BT accounting on a 4x4 mesh.

Builds a small accelerator fabric (memory controller at router 0, PEs on
the remaining routers), injects three kinds of real traffic — conv-platform
packets, a decode weight broadcast, and one ring all-reduce step — and
compares the unsorted fabric against sort-at-source and sort-at-every-hop,
with every link measured by ONE batched Pallas launch.

    PYTHONPATH=src python examples/noc_mesh.py

REPRO_BENCH_TINY=1 (the CI examples-smoke contract) shrinks the injected
payloads.
"""

import os

import jax.numpy as jnp
import numpy as np

from repro.link import LinkSpec
from repro.noc import (
    NocPowerModel,
    conv_platform_flows,
    decode_weight_flows,
    mesh,
    ring_allreduce_flows,
    simulate_noc,
)


def main() -> None:
    rng = np.random.default_rng(0)
    topo = mesh(4, 4)
    pes = [r for r in range(topo.num_routers) if r != 0]

    # input-only framing: one 128-bit weight/activation distribution channel
    spec = LinkSpec(width_bits=128, flits_per_packet=4,
                    input_lanes=16, weight_lanes=0)

    tiny = os.environ.get("REPRO_BENCH_TINY", "") not in ("", "0")
    n_patches, n_out, grad_len = (128, 16, 1 << 12) if tiny else (
        784, 64, 1 << 15
    )
    patches = jnp.asarray(
        rng.integers(0, 256, (n_patches, 25), dtype=np.uint8)
    )
    kernel = jnp.asarray(rng.integers(0, 256, (25,), dtype=np.uint8))
    weight = jnp.asarray(rng.normal(size=(256, n_out)), jnp.float32)
    grad = jnp.asarray(rng.normal(size=(grad_len,)), jnp.float32)

    flows = (
        conv_platform_flows(patches, kernel, topo, 0, pes[:6], spec)
        + decode_weight_flows(weight, topo, 0, topo.row_routers(2), spec)
        + ring_allreduce_flows(grad, topo, routers=range(4), spec=spec)
    )
    print(f"{topo.kind} {topo.rows}x{topo.cols}: {topo.num_links} directed "
          f"links, {len(flows)} flows")

    reports = {}
    for key, sort_at in [("none", "source"), ("acc", "source"), ("acc", "hop")]:
        spec_k = LinkSpec(width_bits=128, flits_per_packet=4,
                          input_lanes=16, weight_lanes=0, key=key)
        reports[(key, sort_at)] = simulate_noc(
            topo, flows, spec_k, sort_at=sort_at, power=NocPowerModel()
        )

    base = reports[("none", "source")]
    print(f"\n{'design':16s} {'total BT':>10s} {'red':>7s} {'energy':>9s} "
          f"{'flit-hops':>9s}")
    for (key, sort_at), rep in reports.items():
        print(f"{key + '-' + sort_at:16s} {rep.total_bt:>10d} "
              f"{100 * rep.reduction_vs(base):>6.2f}% "
              f"{rep.energy_pj / 1e3:>7.1f}nJ {rep.total_flit_hops:>9d}")

    rep = reports[("acc", "source")]
    print(f"\nbusiest links under acc-source ({rep.active_links} active of "
          f"{rep.total_links}):")
    for s in sorted(rep.links, key=lambda s: -s.num_flits)[:6]:
        print(f"  link {s.link:3d} ({s.src:2d} -> {s.dst:2d}): "
              f"{s.num_flits:5d} flits, {s.total_bt:6d} BT "
              f"({s.bt_per_flit:.1f}/flit), {s.energy_pj / 1e3:.2f} nJ")


if __name__ == "__main__":
    main()
